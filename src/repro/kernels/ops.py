"""Public kernel entry points: jitted wrappers that consult the LoopTune
schedule registry for block shapes (the paper's auto-tuned schedules become
BlockSpecs here — `DESIGN §2`).

``set_registry(path_or_registry)`` installs a tuned-schedule table (produced
by ``examples/autotune_matmul.py`` or ``LoopTuner``); every wrapper falls
back to MXU-aligned defaults when no entry exists.  ``interpret`` defaults
to True (CPU container); on a real TPU fleet the launch scripts pass
``interpret=False``.

**Tuned serving** (`launch/serve --registry`): :func:`tuned_einsum` is the
model zoo's consume path.  Inside a :func:`serving` context every
matmul-shaped contraction looks its workload signature up in the active
:class:`ScheduleRegistry` at model-compile (trace) time; hits route through
the Pallas tiled kernel with the tuned BlockSpec on hardware where Mosaic
compiles (``pallas="auto"`` → real TPU), and fall back to the plain
``jnp.einsum`` XLA lowering on cold miss, non-matmul shapes, or CPU hosts
(where interpret-mode Pallas would be a de-optimization).  Per-contraction
hit/miss/routed counters are kept per trace — read them with
:func:`serving_stats`.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.registry import ScheduleRegistry, current_hardware

from .flash_attention import flash_attention as _flash_attention
from .mamba_scan import mamba_scan as _mamba_scan
from .matmul import matmul as _matmul
from .rwkv6_scan import rwkv6_chunk_scan as _rwkv6_chunk_scan

_REGISTRY: Optional[ScheduleRegistry] = None

DEFAULT_MM_BLOCK: Dict[str, int] = {"m": 128, "k": 128, "n": 128}


def set_registry(reg: Union[str, ScheduleRegistry, None]) -> None:
    global _REGISTRY
    if isinstance(reg, str):
        reg = ScheduleRegistry(reg)
    _REGISTRY = reg


def get_registry() -> Optional[ScheduleRegistry]:
    return _REGISTRY


# --------------------------------------------------------------------------
# Tuned serving: trace-time registry context + per-contraction counters
# --------------------------------------------------------------------------

_SERVING: Optional[ScheduleRegistry] = None
_SERVING_STATS: Dict[str, Dict[str, int]] = {}


@contextlib.contextmanager
def serving(registry: Union[str, ScheduleRegistry, None]):
    """Activate a tuned-schedule registry for model tracing.

    The model zoo's matmul sites go through :func:`tuned_einsum`, which
    consults the *active* serving registry.  Because the lookup happens in
    the jitted function body, the context only needs to cover tracing —
    launchers wrap the step-function body so retraces see it too.  ``None``
    deactivates (the default path is untouched ``@``/``einsum``).
    """
    global _SERVING
    if isinstance(registry, str):
        registry = ScheduleRegistry(registry)
    prev = _SERVING
    _SERVING = registry
    try:
        yield registry
    finally:
        _SERVING = prev


def serving_registry() -> Optional[ScheduleRegistry]:
    return _SERVING


def serving_stats(reset: bool = False) -> Dict[str, Any]:
    """Per-contraction registry hit/miss/routed counters (trace-time).

    ``hits``  — workload found in the registry;
    ``misses`` — matmul-shaped contraction with no entry (cold miss);
    ``routed`` — hits actually lowered through the Pallas tiled kernel
    (subset of hits: CPU hosts count the hit but keep the XLA lowering).
    """
    per_key = {k: dict(v) for k, v in _SERVING_STATS.items()}
    out = {
        "hits": sum(v.get("hits", 0) for v in per_key.values()),
        "misses": sum(v.get("misses", 0) for v in per_key.values()),
        "routed": sum(v.get("routed", 0) for v in per_key.values()),
        "per_key": per_key,
    }
    if reset:
        reset_serving_stats()
    return out


def reset_serving_stats() -> None:
    _SERVING_STATS.clear()


def _count(key: str, field: str) -> None:
    slot = _SERVING_STATS.setdefault(key, {"hits": 0, "misses": 0,
                                           "routed": 0})
    slot[field] += 1


def _parse_matmul_spec(spec: str, a_shape, b_shape):
    """Match an einsum spec to a (batched-)matmul; None if not one.

    Accepts two-operand specs where the rhs is 2-D, exactly one index is
    contracted, the contracted index is the trailing lhs dim, and the
    output is ``lhs_free + rhs_free`` — i.e. ``...k,kn->...n`` and the
    transposed-weight form ``...k,nk->...n`` (logits against an embedding
    table).  An lhs/out ellipsis stands for the leading (batch) dims of
    ``a`` and folds into ``m`` exactly like explicit letters, so
    ``"...k,kn->...n"`` and ``"abk,kn->abn"`` on the same shapes resolve to
    the same ``(m, k, n)`` workload key.  Returns
    ``(m, k, n, transpose_rhs)`` with leading lhs dims folded into m,
    matching how ``launch/tune`` harvests workload keys.
    """
    if "->" not in spec:
        return None
    ins, out = spec.split("->")
    if ins.count(",") != 1:
        return None
    lhs, rhs = ins.split(",")
    ellipsis = lhs.startswith("...") and out.startswith("...")
    if ellipsis:
        lhs, out = lhs[3:], out[3:]
    # after stripping a matched lhs/out prefix, any remaining "..." (rhs
    # ellipsis, mid-spec, or one side only) is a shape we don't tune
    if "..." in lhs or "..." in rhs or "..." in out:
        return None
    if ellipsis:
        # the ellipsis absorbs len(a_shape) - len(lhs) leading batch dims;
        # the explicit letters must still cover at least the contracted dim
        if not lhs or len(lhs) > len(a_shape):
            return None
    elif len(lhs) != len(a_shape):
        return None
    if len(rhs) != 2 or len(rhs) != len(b_shape):
        return None
    if len(set(lhs)) != len(lhs) or len(set(rhs)) != len(rhs):
        return None
    contracted = (set(lhs) & set(rhs)) - set(out)
    if len(contracted) != 1:
        return None
    ck = contracted.pop()
    if lhs[-1] != ck:
        return None
    free_l = lhs[:-1]
    free_r = rhs.replace(ck, "")
    if out != free_l + free_r:
        return None
    m = 1
    for d in a_shape[:-1]:
        m *= int(d)
    k = int(a_shape[-1])
    n = int(b_shape[1] if rhs[0] == ck else b_shape[0])
    return m, k, n, rhs[0] != ck


def _route_pallas(pallas: str) -> Tuple[bool, bool]:
    """(route through Pallas?, interpret mode?) for a registry hit.

    ``"auto"`` routes only where Mosaic compiles (real TPU) — on CPU the
    interpret-mode kernel is a de-optimization, so hits keep the XLA
    lowering (still counted, proving the lookup path).  ``"interpret"``
    forces the interpreted kernel (tests), ``"on"`` the compiled one,
    ``"off"`` never routes.
    """
    if pallas == "off":
        return False, True
    if pallas == "interpret":
        return True, True
    if pallas == "on":
        return True, False
    return jax.default_backend() == "tpu", False


def tuned_einsum(spec: str, a: jax.Array, b: jax.Array, *,
                 registry: Optional[ScheduleRegistry] = None,
                 pallas: str = "auto",
                 preferred_element_type=None) -> jax.Array:
    """Registry-backed einsum: the model zoo's tuned-serving entry point.

    Looks the contraction's workload signature up in ``registry`` (default:
    the active :func:`serving` registry) at trace time.  On a hit with a
    tuned block, matmul-shaped contractions route through the Pallas tiled
    kernel with the tuned BlockSpec; cold misses, non-matmul shapes, and
    hosts where Mosaic can't compile fall back to ``jnp.einsum`` — always
    numerically interchangeable with the fallback.
    """
    reg = registry if registry is not None else _SERVING

    def _fallback():
        return jnp.einsum(spec, a, b,
                          preferred_element_type=preferred_element_type)

    if reg is None:
        return _fallback()
    parsed = _parse_matmul_spec(spec, a.shape, b.shape)
    if parsed is None:
        return _fallback()
    m, k, n, transpose_rhs = parsed
    dtype = str(a.dtype)
    wl_key = ScheduleRegistry.key("mm", (m, k, n), dtype)
    entry = reg.get("mm", (m, k, n), dtype=dtype,
                    hardware=current_hardware())
    if not entry or "block" not in entry:
        _count(wl_key, "misses")
        return _fallback()
    _count(wl_key, "hits")
    route, interpret = _route_pallas(pallas)
    if not route:
        return _fallback()
    _count(wl_key, "routed")
    block = dict(DEFAULT_MM_BLOCK)
    block.update({kk: int(vv) for kk, vv in entry["block"].items()})
    go = [it for it in entry.get("grid_order", []) if it in ("m", "n")]
    order = "nm" if go and go[0] == "n" else "mn"
    a2 = a.reshape(m, k)
    b2 = b.T if transpose_rhs else b
    out_dtype = preferred_element_type if preferred_element_type is not None \
        else a.dtype
    out = _matmul(a2, b2, bm=block["m"], bk=block["k"], bn=block["n"],
                  grid_order=order, interpret=interpret, out_dtype=out_dtype)
    return out.reshape(*a.shape[:-1], n)


def _mm_schedule(m: int, k: int, n: int):
    """(block sizes, grid order) for an (m, k, n) matmul from the registry."""
    block = dict(DEFAULT_MM_BLOCK)
    order = "mn"
    if _REGISTRY is not None:
        entry = _REGISTRY.get("mm", (m, k, n))
        if entry and "block" in entry:
            block.update({kk: int(vv) for kk, vv in entry["block"].items()})
            go = [it for it in entry.get("grid_order", []) if it in ("m", "n")]
            if go and go[0] == "n":
                order = "nm"
    return block, order


def tuned_matmul(a: jax.Array, b: jax.Array, *, interpret: bool = True,
                 out_dtype=None) -> jax.Array:
    """Registry-tuned tiled matmul (falls back to 128^3 MXU blocks)."""
    m, k = a.shape
    n = b.shape[1]
    block, order = _mm_schedule(m, k, n)
    return _matmul(a, b, bm=block["m"], bk=block["k"], bn=block["n"],
                   grid_order=order, interpret=interpret, out_dtype=out_dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    interpret: bool = True):
    """Registry-tuned flash attention (block sizes under kernel id 'fa')."""
    bq, bk = 128, 128
    if _REGISTRY is not None:
        entry = _REGISTRY.get("fa", (q.shape[1], k.shape[1], q.shape[-1]))
        if entry and "block" in entry:
            bq = int(entry["block"].get("q", bq))
            bk = int(entry["block"].get("k", bk))
    return _flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, bq=bq, bk=bk,
                            interpret=interpret)


def rwkv6_chunk_scan(r, k, v, logw, u, *, chunk: int = 64,
                     interpret: bool = True):
    if _REGISTRY is not None:
        entry = _REGISTRY.get("rwkv6", (r.shape[1], r.shape[2]))
        if entry and "block" in entry:
            chunk = int(entry["block"].get("l", chunk))
    return _rwkv6_chunk_scan(r, k, v, logw, u, chunk=chunk,
                             interpret=interpret)


def mamba_scan(dtx, da, b, c, *, chunk: int = 32, bd: int = 128,
               interpret: bool = True):
    if _REGISTRY is not None:
        entry = _REGISTRY.get("mamba", (dtx.shape[1], dtx.shape[2]))
        if entry and "block" in entry:
            chunk = int(entry["block"].get("l", chunk))
            bd = int(entry["block"].get("c", bd))
    return _mamba_scan(dtx, da, b, c, chunk=chunk, bd=bd, interpret=interpret)
