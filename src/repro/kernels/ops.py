"""Public kernel entry points: jitted wrappers that consult the LoopTune
schedule registry for block shapes (the paper's auto-tuned schedules become
BlockSpecs here — `DESIGN §2`).

``set_registry(path_or_registry)`` installs a tuned-schedule table (produced
by ``examples/autotune_matmul.py`` or ``LoopTuner``); every wrapper falls
back to MXU-aligned defaults when no entry exists.  ``interpret`` defaults
to True (CPU container); on a real TPU fleet the launch scripts pass
``interpret=False``.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.registry import ScheduleRegistry

from .flash_attention import flash_attention as _flash_attention
from .mamba_scan import mamba_scan as _mamba_scan
from .matmul import matmul as _matmul
from .rwkv6_scan import rwkv6_chunk_scan as _rwkv6_chunk_scan

_REGISTRY: Optional[ScheduleRegistry] = None

DEFAULT_MM_BLOCK: Dict[str, int] = {"m": 128, "k": 128, "n": 128}


def set_registry(reg: Union[str, ScheduleRegistry, None]) -> None:
    global _REGISTRY
    if isinstance(reg, str):
        reg = ScheduleRegistry(reg)
    _REGISTRY = reg


def get_registry() -> Optional[ScheduleRegistry]:
    return _REGISTRY


def _mm_schedule(m: int, k: int, n: int):
    """(block sizes, grid order) for an (m, k, n) matmul from the registry."""
    block = dict(DEFAULT_MM_BLOCK)
    order = "mn"
    if _REGISTRY is not None:
        entry = _REGISTRY.get("mm", (m, k, n))
        if entry and "block" in entry:
            block.update({kk: int(vv) for kk, vv in entry["block"].items()})
            go = [it for it in entry.get("grid_order", []) if it in ("m", "n")]
            if go and go[0] == "n":
                order = "nm"
    return block, order


def tuned_matmul(a: jax.Array, b: jax.Array, *, interpret: bool = True,
                 out_dtype=None) -> jax.Array:
    """Registry-tuned tiled matmul (falls back to 128^3 MXU blocks)."""
    m, k = a.shape
    n = b.shape[1]
    block, order = _mm_schedule(m, k, n)
    return _matmul(a, b, bm=block["m"], bk=block["k"], bn=block["n"],
                   grid_order=order, interpret=interpret, out_dtype=out_dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    interpret: bool = True):
    """Registry-tuned flash attention (block sizes under kernel id 'fa')."""
    bq, bk = 128, 128
    if _REGISTRY is not None:
        entry = _REGISTRY.get("fa", (q.shape[1], k.shape[1], q.shape[-1]))
        if entry and "block" in entry:
            bq = int(entry["block"].get("q", bq))
            bk = int(entry["block"].get("k", bk))
    return _flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, bq=bq, bk=bk,
                            interpret=interpret)


def rwkv6_chunk_scan(r, k, v, logw, u, *, chunk: int = 64,
                     interpret: bool = True):
    if _REGISTRY is not None:
        entry = _REGISTRY.get("rwkv6", (r.shape[1], r.shape[2]))
        if entry and "block" in entry:
            chunk = int(entry["block"].get("l", chunk))
    return _rwkv6_chunk_scan(r, k, v, logw, u, chunk=chunk,
                             interpret=interpret)


def mamba_scan(dtx, da, b, c, *, chunk: int = 32, bd: int = 128,
               interpret: bool = True):
    if _REGISTRY is not None:
        entry = _REGISTRY.get("mamba", (dtx.shape[1], dtx.shape[2]))
        if entry and "block" in entry:
            chunk = int(entry["block"].get("l", chunk))
            bd = int(entry["block"].get("c", bd))
    return _mamba_scan(dtx, da, b, c, chunk=chunk, bd=bd, interpret=interpret)
