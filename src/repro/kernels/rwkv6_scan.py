"""RWKV-6 chunked-scan Pallas kernel.

The Finch recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t is attention-free
and O(S) — the LoopTune-relevant structure is the *chunk*: within a chunk of
L tokens the recurrence unrolls into dense (L, N) x (N, N) and strictly
lower-triangular (L, L) matmuls (MXU work); across chunks a tiny (N, N) f32
state is carried in VMEM scratch.

Grid ``(B*H, n_chunks)`` with the chunk dimension innermost (sequential):
the state scratch persists across chunk steps, so each (batch, head) stream
is scanned without the state ever leaving VMEM.

Inputs are per-head streams (B*H, S, N) with N = head_dim; decay ``logw`` is
the log-space data-dependent decay (<= 0).  Validated against
``ref.rwkv6_ref`` (the token-by-token recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref,
                 s_ref, *, n_chunks: int, chunk: int, seq: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)   # (L, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = w_ref[0].astype(jnp.float32)  # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)   # (N,) bonus

    # state-neutral padding (k = 0, logw = 0) for positions >= seq
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = pos < seq
    k = jnp.where(valid, k, 0.0)
    lw = jnp.where(valid, lw, 0.0)

    cum = jnp.cumsum(lw, axis=0)       # inclusive log-decay products
    cum_ex = cum - lw                  # exclusive
    s = s_ref[...]                     # (N, N) carried state

    r_dec = r * jnp.exp(cum_ex)
    y = jnp.dot(r_dec, s, preferred_element_type=jnp.float32)  # inter-chunk
    k_dec = k * jnp.exp(-cum)
    att = jnp.dot(r_dec, k_dec.T, preferred_element_type=jnp.float32)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(li > lj, att, 0.0)  # strictly causal intra-chunk
    diag = jnp.sum(r * (u[None, :] * k), axis=-1)  # u-bonus for t == i
    y = y + jnp.dot(att, v, preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S' = diag(prod w) S + sum_i (k_i * W_L / W_i)^T v_i
    w_last = cum[-1:, :]               # (1, N)
    k_carry = k * jnp.exp(w_last - cum)
    s_ref[...] = s * jnp.exp(w_last[0])[:, None] + jnp.dot(
        k_carry.T, v, preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _done():
        sout_ref[0] = s_ref[...]


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunk_scan(
    r: jax.Array,     # (BH, S, N)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (BH, S, N) log-space decay (<= 0), f32
    u: jax.Array,     # (BH, N) per-head bonus
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    """Returns (y (BH, S, N) f32, final_state (BH, N, N) f32)."""
    bh, s, n = r.shape
    chunk = min(chunk, s)
    pad = -s % chunk
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v, logw = zp(r), zp(k), zp(v), zp(logw)
    n_chunks = _cdiv(s + pad, chunk)

    y, s_out = pl.pallas_call(
        functools.partial(_rwkv_kernel, n_chunks=n_chunks, chunk=chunk, seq=s),
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, n), lambda h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, n, n), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s + pad, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return y[:, :s], s_out
