"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Grid ``(batch*heads, n_q_blocks, n_kv_blocks)`` with the kv dimension
innermost (sequential): the f32 accumulator / running-max / running-sum live
in VMEM scratch across kv steps — the online-softmax state never touches
HBM.  Supports causal masking, sliding windows and gemma-style score
softcaps; the block shapes come from the LoopTune schedule registry via
``ops.py``.

The pure-jnp oracle is ``ref.attention_ref`` (the same math as
``repro.models.layers.attention``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               n_kv: int, bq: int, bk: int, causal: bool, scale: float,
               softcap: Optional[float], window: Optional[int],
               seq_q: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (q_pos < seq_q) & (kv_pos < seq_kv)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + p.sum(axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, HKV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:  # GQA: expand KV heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scale = 1.0 / math.sqrt(d)
    bq = min(bq, s)
    bk = min(bk, t)

    # (B*H, S, D) layout; pad seq dims to block multiples
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, t, d)
    ps, pt = -s % bq, -t % bk
    if ps:
        qf = jnp.pad(qf, ((0, 0), (0, ps), (0, 0)))
    if pt:
        kf = jnp.pad(kf, ((0, 0), (0, pt), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pt), (0, 0)))
    n_q, n_kv = _cdiv(s + ps, bq), _cdiv(t + pt, bk)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, n_kv=n_kv, bq=bq, bk=bk, causal=causal, scale=scale,
            softcap=softcap, window=window, seq_q=s, seq_kv=t),
        grid=(b * hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s + ps, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :s].reshape(b, hq, s, d).transpose(0, 2, 1, 3)
