"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling) for the
compute hot-spots LoopTune schedules, each with a jit wrapper (ops.py) and a
pure-jnp oracle (ref.py).  Validated in interpret mode on CPU."""
from .ops import (
    flash_attention,
    get_registry,
    mamba_scan,
    rwkv6_chunk_scan,
    set_registry,
    tuned_matmul,
)

__all__ = [
    "flash_attention",
    "mamba_scan",
    "rwkv6_chunk_scan",
    "tuned_matmul",
    "set_registry",
    "get_registry",
]
