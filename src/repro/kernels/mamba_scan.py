"""Mamba selective-scan Pallas kernel.

The discretized SSM h_t = decay_t * h_{t-1} + dt_t B_t x_t, y_t = C_t . h_t
is scanned per chunk: the (bd, N) hidden state lives in VMEM scratch and the
in-chunk recurrence uses a log-space cumulative-product trick — within a
chunk the state contribution of token i to token t is
``exp(cumA_t - cumA_i)``, so the chunk reduces to two matmuls plus a masked
(L, L) combine (MXU-friendly; the per-channel scan never materializes in
HBM).

Grid ``(B, n_d_blocks, n_chunks)``; chunks innermost (sequential) carrying
the state; the d_inner dimension is blocked with ``bd`` (the LoopTune-tuned
tile).  Validated against ``ref.mamba_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(dtx_ref, da_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                  n_chunks: int, chunk: int, seq: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dtx = dtx_ref[0].astype(jnp.float32)  # (L, bd)   dt_t * x_t
    da = da_ref[0].astype(jnp.float32)    # (L, bd, N) dt_t * A  (log decay)
    bm = b_ref[0].astype(jnp.float32)     # (L, N)
    cm = c_ref[0].astype(jnp.float32)     # (L, N)

    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, 1), 0)
    valid = pos < seq
    dtx = jnp.where(valid[..., 0], dtx, 0.0)
    da = jnp.where(valid, da, 0.0)  # exp(0) = 1: state-neutral padding

    # u_t = dt_t x_t B_t : (L, bd, N)
    u = dtx[:, :, None] * bm[:, None, :]
    cum = jnp.cumsum(da, axis=0)          # (L, bd, N) inclusive log-decay
    h0 = h_ref[...]                       # (bd, N) carried state

    # h_t = exp(cum_t) h0 + sum_{i<=t} exp(cum_t - cum_i) u_i
    # y_t = C_t . h_t  (reduce over N)
    contrib = u * jnp.exp(-cum)
    csum = jnp.cumsum(contrib, axis=0)
    h_all = jnp.exp(cum) * (h0[None] + csum)  # (L, bd, N)
    y = jnp.einsum("lbn,ln->lb", h_all, cm)
    y_ref[0] = y.astype(y_ref.dtype)

    h_ref[...] = h_all[-1]

    @pl.when(ci == n_chunks - 1)
    def _done():
        hout_ref[0] = h_ref[...]


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def mamba_scan(
    dtx: jax.Array,   # (B, S, C)      dt_t * x_t
    da: jax.Array,    # (B, S, C, N)   dt_t * A   (log decay, <= 0)
    b: jax.Array,     # (B, S, N)
    c: jax.Array,     # (B, S, N)
    *,
    chunk: int = 32,
    bd: int = 128,
    interpret: bool = True,
):
    """Returns (y (B, S, C) f32, final_state (B, C, N) f32)."""
    bsz, s, ch = dtx.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    bd = min(bd, ch)
    ps, pc = -s % chunk, -ch % bd
    if ps or pc:
        dtx = jnp.pad(dtx, ((0, 0), (0, ps), (0, pc)))
        da = jnp.pad(da, ((0, 0), (0, ps), (0, pc), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, ps), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, ps), (0, 0)))
    n_chunks = _cdiv(s + ps, chunk)
    n_d = _cdiv(ch + pc, bd)

    y, h_out = pl.pallas_call(
        functools.partial(_mamba_kernel, n_chunks=n_chunks, chunk=chunk,
                          seq=s),
        grid=(bsz, n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, bd, n), lambda i, j, k: (i, k, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, bd, n), lambda i, j, k: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s + ps, ch + pc), jnp.float32),
            jax.ShapeDtypeStruct((bsz, ch + pc, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(dtx, da, b, c)
    return y[:, :s, :ch], h_out[:, :ch]
