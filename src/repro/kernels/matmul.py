"""Tiled matmul Pallas kernel — the kernel LoopTune schedules.

The tuned loop nest lowers onto this kernel: the VMEM-resident suffix of the
schedule becomes the BlockSpec block shape ``(bm, bk, bn)`` and the grid
iterates the outer levels in schedule order (``grid_order``).  The k grid
dimension is always innermost (sequential) so the f32 VMEM scratch
accumulator implements LoopNest's register tiling: the output tile stays
resident across the whole contraction and is written back exactly once.

Validated against ``ref.matmul_ref`` in interpret mode (CPU); on TPU the
same ``pl.pallas_call`` compiles to a Mosaic kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k_i = pl.program_id(2)

    @pl.when(k_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_i == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "grid_order", "interpret", "out_dtype"),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    grid_order: str = "mn",  # outer-grid traversal: "mn" | "nm"
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    """C[m, n] = A[m, k] @ B[k, n] with explicit VMEM tiling.

    Non-divisible dims are zero-padded (zeros are sum-neutral) and the
    output sliced back — the ``tail`` semantics of the loop IR.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)

    pm, pk, pn = -m % bm, -k % bk, -n % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gn, gk = _cdiv(m + pm, bm), _cdiv(n + pn, bn), _cdiv(k + pk, bk)

    if grid_order == "mn":
        grid = (gm, gn, gk)
        a_map = lambda i, j, kk: (i, kk)
        b_map = lambda i, j, kk: (kk, j)
        o_map = lambda i, j, kk: (i, j)
    else:  # "nm": n outermost
        grid = (gn, gm, gk)
        a_map = lambda j, i, kk: (i, kk)
        b_map = lambda j, i, kk: (kk, j)
        o_map = lambda j, i, kk: (i, j)

    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_k=gk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
