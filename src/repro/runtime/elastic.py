"""Elastic scaling: reshard a checkpointed state across a different mesh.

``remesh(state, new_mesh, spec_fn)`` re-places every leaf under the new
mesh/sharding — the mechanism behind shrinking 2 pods -> 1 pod after a pod
loss, or growing when capacity returns.  On the CPU container this is
exercised with ``xla_force_host_platform_device_count`` sub-process tests
(1 -> 8 logical devices); on a fleet the same code runs over real meshes
because only ``jax.device_put`` semantics are involved.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh(state: Any, mesh: Mesh, spec_tree: Any) -> Any:
    """Re-place ``state`` so each leaf has its spec under ``mesh``."""

    def one(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        one, state, spec_tree,
        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))


def shrink_batch_for(mesh: Mesh, global_batch: int,
                     rules: Optional[Dict] = None) -> int:
    """Largest batch <= global_batch divisible by the mesh's batch axes
    (elastic data parallelism keeps per-device batch constant)."""
    from .sharding import DEFAULT_RULES

    rules = dict(rules or DEFAULT_RULES)
    axes = tuple(a for a in rules["batch"] if a in mesh.axis_names)
    bsize = math.prod(mesh.shape[a] for a in axes) if axes else 1
    return max(bsize, (global_batch // bsize) * bsize)
