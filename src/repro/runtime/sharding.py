"""Logical-axis sharding rules (MaxText-style) + mesh context.

Physical meshes (``launch/mesh.py``):
    single-pod  (data=16, model=16)            — v5e-256
    multi-pod   (pod=2, data=16, model=16)     — 2 pods, 512 chips

Logical axes used by models / optimizer / caches:

    batch   -> (pod, data)      activations' leading dim
    model   -> model            generic tensor-parallel dim
    heads   -> model            attention Q heads
    kv      -> model            attention KV heads (replicated if indivisible)
    mlp     -> model            FFN hidden
    expert  -> model            MoE expert dim (expert parallelism)
    vocab   -> model            vocab-parallel embedding / logits
    seq     -> data             long-context decode: KV cache sequence dim
    zero    -> data             optimizer-state sharding (ZeRO-1/2)

Every rule applies **only when the dim is divisible** by the mesh-axis
product; otherwise the dim is replicated and the fallback is recorded in
:data:`FALLBACKS` (DESIGN §5: llama4's 40 Q-heads on model=16, kv_heads=8 on
model=16, ...).
"""
from __future__ import annotations

import math
import re
import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()

# logical -> physical mesh axis (tuples allowed)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "model": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "seq": ("data",),
    "zero": ("data",),
    # sequence parallelism: residual-stream seq dim between blocks -> model
    # (GSPMD inserts the all-gather before attention / reduce-scatter after,
    # so the n_periods saved scan carries are 1/model_size the size)
    "act_seq": ("model",),
    # flattened token dim (MoE dispatch): all mesh axes
    "tokens": ("pod", "data", "model"),
    # token dim sharded over data only (MoE internals keep tokens on
    # (pod, data) so the expert buffers can take (model, data))
    "tokens_dp": ("pod", "data"),
    # expert FFN hidden dim: static 2nd shard axis for expert weights
    # (expert -> model, d_ff_expert -> data).  Fully 2D-sharded expert
    # weights never need FSDP gathers — the (small) dispatched activations
    # reshard instead of the (huge) weights.
    "expert_ff": ("data",),
}

FALLBACKS: List[str] = []  # record of replication fallbacks (for DESIGN/EXPERIMENTS)


def _record_fallback(msg: str) -> None:
    if msg not in FALLBACKS:
        FALLBACKS.append(msg)


@contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Install the mesh + rules for :func:`ashard` activation constraints."""
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, dict(rules or DEFAULT_RULES))
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_TLS, "ctx", None)
    return ctx[0] if ctx else None


def _resolve(mesh: Mesh, rules, logical: Optional[str], dim: int):
    """Logical axis -> physical axes for a concrete dim, or None (replicate)."""
    if logical is None:
        return None
    phys = tuple(a for a in rules.get(logical, ()) if a in mesh.axis_names)
    if not phys:
        return None
    prod = math.prod(mesh.shape[a] for a in phys)
    if dim % prod != 0:
        _record_fallback(f"dim {dim} ({logical}) % {prod} != 0 -> replicated")
        return None
    return phys if len(phys) > 1 else phys[0]


def logical_spec(mesh: Mesh, rules, axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> P:
    return P(*(_resolve(mesh, rules, ax, d) for ax, d in zip(axes, shape)))


def ashard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Activation sharding constraint; no-op outside a mesh context."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(mesh, rules, axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules (path + shape -> logical axes)
# ---------------------------------------------------------------------------

# (regex on the flattened path, logical axes for the TRAILING dims).
# Leading dims not covered (e.g. the n_periods stack axis) are replicated.
_PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # embeddings / head: vocab-parallel
    (r"embed.*table", ("vocab", None)),
    (r"lm_head", ("vocab", None)),
    # MoE: expert parallelism (experts over model axis; expert-internal dims
    # stay local so each expert's FFN runs on one shard group).  Shared-
    # expert rules must precede the generic expert rules (both match "moe.").
    (r"moe.*router", (None, None)),
    (r"moe.*shared.*w_(gate|up)$", (None, "mlp")),
    (r"moe.*shared.*w_down", ("mlp", None)),
    (r"moe.*w_(gate|up)$", ("expert", None, "expert_ff")),
    (r"moe.*w_down", ("expert", "expert_ff", None)),
    # attention projections (column-parallel in, row-parallel out)
    (r"attn.*w(q)$|cross.*wq$", (None, "heads")),
    (r"attn.*w(k|v)$|cross.*w(k|v)$", (None, "kv")),
    (r"attn.*wo$|cross.*wo$", ("heads", None)),
    (r"b(q)$", ("heads",)),
    (r"b(k|v)$", ("kv",)),
    # dense mlp
    (r"mlp.*w_(gate|up)$", (None, "mlp")),
    (r"mlp.*w_down", ("mlp", None)),
    # rwkv time-mix (heads over model via the flattened d axis)
    (r"rwkv.*w_(r|k|v|g)$", (None, "model")),
    (r"rwkv.*w_o$", ("model", None)),
    (r"rwkv.*u$", ("model", None)),
    (r"rwkv.*w_lora_a", (None, None)),
    (r"rwkv.*w_lora_b", (None, "model")),
    (r"rwkv.*w0", ("model",)),
    # rwkv channel-mix
    (r"cmix.*w_k$", (None, "mlp")),
    (r"cmix.*w_v$", ("mlp", None)),
    (r"cmix.*w_r$", (None, "model")),
    # mamba (d_inner over model)
    (r"mamba.*in_proj", (None, "model")),
    (r"mamba.*conv_w", (None, "model")),
    (r"mamba.*conv_b", ("model",)),
    (r"mamba.*x_proj", ("model", None)),
    (r"mamba.*dt_proj", (None, "model")),
    (r"mamba.*dt_bias", ("model",)),
    (r"mamba.*a_log", ("model", None)),
    (r"mamba.*\bd\b", ("model",)),
    (r"mamba.*out_proj", ("model", None)),
]


def _path_str(path) -> str:
    return jax.tree_util.keystr(path).replace("'", "").replace("]", "").replace(
        "[", ".")


def _axes_for(path_str: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path_str):
            if len(axes) > ndim:
                return (None,) * ndim
            return (None,) * (ndim - len(axes)) + tuple(axes)
    return (None,) * ndim  # norms, scalars, mu vectors: replicated


def param_pspecs(params_tree: Any, mesh: Mesh,
                 rules: Optional[Dict] = None,
                 special_kv_heads: Optional[int] = None) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays).

    ``special_kv_heads``: if given, KV projections are only sharded when the
    *head count* divides the model axis (a flat-dim divisibility check would
    wrongly split single heads across shards)."""
    rules = dict(rules or DEFAULT_RULES)
    model_size = math.prod(
        mesh.shape[a] for a in rules["kv"] if a in mesh.axis_names) or 1

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        axes = _axes_for(ps, len(shape))
        if special_kv_heads is not None and "kv" in axes:
            if special_kv_heads % model_size != 0:
                _record_fallback(
                    f"kv_heads={special_kv_heads} % model={model_size} != 0 "
                    f"-> KV projections replicated ({ps})")
                axes = tuple(None if a == "kv" else a for a in axes)
        return logical_spec(mesh, rules, axes, shape)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def zero_pspecs(param_specs: Any, shapes: Any, mesh: Mesh,
                rules: Optional[Dict] = None,
                min_size: int = 0) -> Any:
    """ZeRO/FSDP sharding: spec + 'data' on the first unsharded dim that
    divides the data axis.  Applied to optimizer state (ZeRO-1/2) and — via
    :func:`fsdp_pspecs` — to the bf16 params themselves (FSDP; GSPMD inserts
    the per-layer all-gather inside the period scan).  ``min_size`` skips
    small leaves (norm scales etc.) where gather latency beats memory."""
    rules = dict(rules or DEFAULT_RULES)
    data_axes = tuple(a for a in rules["zero"] if a in mesh.axis_names)
    if not data_axes:
        return param_specs
    dsize = math.prod(mesh.shape[a] for a in data_axes)

    def _uses_data(parts) -> bool:
        for p in parts:
            for a in (p if isinstance(p, tuple) else (p,)):
                if a in data_axes:
                    return True
        return False

    def one(spec: P, leaf) -> P:
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        if math.prod(leaf.shape) < min_size or _uses_data(parts):
            return P(*parts)  # small, or already data-sharded (2D experts)
        for i, (p, d) in enumerate(zip(parts, leaf.shape)):
            if p is None and d % dsize == 0 and d >= dsize:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                return P(*parts)
        return P(*parts)

    return jax.tree_util.tree_map(one, param_specs, shapes)


def fsdp_pspecs(param_specs: Any, shapes: Any, mesh: Mesh,
                rules: Optional[Dict] = None) -> Any:
    """FSDP param sharding: TP spec + data axis on large leaves (>= 1M
    elements).  Small leaves stay TP-only to avoid gather latency."""
    return zero_pspecs(param_specs, shapes, mesh, rules, min_size=1 << 20)


# ---------------------------------------------------------------------------
# Cache / activation input specs
# ---------------------------------------------------------------------------


def cache_pspecs(cache_tree: Any, mesh: Mesh, batch: int,
                 kv_heads: int, rules: Optional[Dict] = None) -> Any:
    """Decode-cache specs.  Normal decode: batch over (pod, data), heads over
    model.  batch=1 long-context: sequence dim over data (flash-decode style;
    GSPMD inserts the partial-softmax combine collectives)."""
    rules = dict(rules or DEFAULT_RULES)
    batch_axes = tuple(a for a in rules["batch"] if a in mesh.axis_names)
    bsize = math.prod(mesh.shape[a] for a in batch_axes) if batch_axes else 1
    batch_ok = batch % bsize == 0 and batch >= bsize
    model_size = math.prod(
        mesh.shape[a] for a in rules["model"] if a in mesh.axis_names) or 1

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        b_ax = (batch_axes if len(batch_axes) > 1 else batch_axes[0]) \
            if (batch_ok and batch_axes) else None
        if re.search(r"\.(k|v|ck|cv)$", ps) and nd == 5:
            # (n_periods, B, T, HKV, D).  Preference order for the model
            # axis: KV heads when divisible, else the sequence dim (the
            # decode path reduces over T with plain all-reduces).  batch=1
            # long-context shards T over data as well.
            head_ax = "model" if kv_heads % model_size == 0 else None
            seq_parts = []
            if not batch_ok:
                seq_parts += list(
                    a for a in rules["seq"] if a in mesh.axis_names)
            if head_ax is None:
                seq_parts += list(
                    a for a in rules["act_seq"] if a in mesh.axis_names)
            seq_ax = None
            if seq_parts:
                prod = math.prod(mesh.shape[a] for a in seq_parts)
                if shape[2] % prod == 0:
                    seq_ax = tuple(seq_parts) if len(seq_parts) > 1 \
                        else seq_parts[0]
                else:
                    _record_fallback(
                        f"cache seq {shape[2]} % {prod} != 0 -> replicated")
            return P(None, b_ax, seq_ax, _resolve(mesh, rules, head_ax, shape[3])
                     if head_ax else None, None)
        if re.search(r"\.(h|conv)$", ps) and nd >= 3:
            # mamba: (n_periods, B, ..., d_inner[, N]) — d_inner over model
            inner_axis = 2 if ps.endswith(".h") else 3
            parts = [None] * nd
            parts[1] = b_ax
            parts[inner_axis] = _resolve(mesh, rules, "model", shape[inner_axis])
            return P(*parts)
        if re.search(r"\.s$", ps) and nd == 5:
            # rwkv state (n_periods, B, H, N, N) — heads over model
            return P(None, b_ax, _resolve(mesh, rules, "model", shape[2]),
                     None, None)
        parts = [None] * nd
        if nd >= 2:
            parts[1] = b_ax
        return P(*parts)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def batch_pspec(mesh: Mesh, batch: int, ndim: int,
                rules: Optional[Dict] = None) -> P:
    rules = dict(rules or DEFAULT_RULES)
    axes = tuple(a for a in rules["batch"] if a in mesh.axis_names)
    bsize = math.prod(mesh.shape[a] for a in axes) if axes else 1
    if not axes or batch % bsize != 0:
        return P(*([None] * ndim))
    return P(axes if len(axes) > 1 else axes[0], *([None] * (ndim - 1)))


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
