"""Distributed runtime: sharding rules, mesh context, fault tolerance,
straggler mitigation, elastic remesh, gradient compression."""
