"""Gradient compression: int8 quantized all-reduce with error feedback.

``make_compressor()`` returns a grad_transform for ``make_train_step``:
each leaf is quantized to int8 with a per-leaf scale *before* the (GSPMD-
inserted) gradient reduction, and the quantization residual is fed back
into the next step (error feedback keeps the compression unbiased over
time — Seide et al. 2014 / Karimireddy et al. 2019).  4x less all-reduce
traffic at <1e-2 relative error per step; off by default.

The error-feedback state is a pytree carried by the caller (it must live in
the train state to survive checkpoints), so the transform is a pure
function: ``grads, new_ef = compress(grads, ef)``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Quantize (grads + ef) to int8; residual becomes the new ef.

    Two passes (XLA CSEs the duplicate quantization under jit) — a single
    tuple-returning tree_map would mis-treat tuple-structured param trees.
    """

    def deq_one(g, e):
        q, scale = quantize_int8(g.astype(jnp.float32) + e)
        return dequantize_int8(q, scale)

    deq = jax.tree.map(deq_one, grads, ef)
    new_ef = jax.tree.map(
        lambda g, e, d: g.astype(jnp.float32) + e - d, grads, ef, deq)
    return deq, new_ef
