"""Fault tolerance for the training loop (DESIGN §6).

* :class:`FaultTolerantRunner` — wraps the jitted step: on a device/host
  failure (any exception from the step, including injected ones) it reloads
  the latest checkpoint and replays from there.  Because the data pipeline
  is a pure function of the step counter, the replayed batches are identical
  — deterministic restart.
* :class:`StragglerWatchdog` — per-host step-time EWMA + robust z-score;
  hosts slower than ``k`` MADs above the median for ``patience`` consecutive
  steps are flagged (on a fleet the controller would evict/reshard; here the
  policy hook fires and the event is logged).
* :class:`FailureInjector` — deterministic fault schedule for tests/examples
  ("fail at step 7 twice").
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.ft")


class FailureInjector:
    """Raises at scheduled steps (each entry fires once)."""

    def __init__(self, fail_steps: Optional[List[int]] = None):
        self.pending = sorted(fail_steps or [])
        self.fired: List[int] = []

    def maybe_fail(self, step: int) -> None:
        if self.pending and step >= self.pending[0]:
            s = self.pending.pop(0)
            self.fired.append(s)
            raise RuntimeError(f"injected device failure at step {s}")


@dataclass
class StragglerWatchdog:
    n_hosts: int
    k_mads: float = 4.0
    patience: int = 3
    ewma: float = 0.7
    on_straggler: Optional[Callable[[int, float], None]] = None
    _t: Optional[np.ndarray] = None
    _bad: Optional[np.ndarray] = None
    events: List[Tuple[int, int, float]] = field(default_factory=list)

    def record(self, step: int, host_times: np.ndarray) -> List[int]:
        """host_times: per-host step seconds.  Returns flagged host ids."""
        host_times = np.asarray(host_times, np.float64)
        if self._t is None:
            self._t = host_times.copy()
            self._bad = np.zeros(self.n_hosts, np.int32)
        else:
            self._t = self.ewma * self._t + (1 - self.ewma) * host_times
        med = np.median(self._t)
        mad = np.median(np.abs(self._t - med)) + 1e-9
        slow = self._t > med + self.k_mads * mad
        self._bad = np.where(slow, self._bad + 1, 0)
        flagged = [int(h) for h in np.flatnonzero(self._bad >= self.patience)]
        for h in flagged:
            self.events.append((step, h, float(self._t[h])))
            if self.on_straggler:
                self.on_straggler(h, float(self._t[h]))
            self._bad[h] = 0  # re-arm after firing
        return flagged


class FaultTolerantRunner:
    """step_fn(state, batch) -> (state, metrics); state is any pytree."""

    def __init__(
        self,
        step_fn: Callable,
        ckpt_manager,
        save_every: int = 50,
        max_restarts: int = 5,
        injector: Optional[FailureInjector] = None,
        extras_fn: Optional[Callable[[int], dict]] = None,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.extras_fn = extras_fn
        self.restarts = 0
        self.restart_log: List[Tuple[int, str]] = []

    def run(
        self,
        state: Any,
        batch_fn: Callable[[int], Any],
        start_step: int,
        n_steps: int,
        hooks: Optional[List[Callable[[int, dict], None]]] = None,
    ) -> Tuple[Any, int, List[dict]]:
        """Runs to ``start_step + n_steps`` surviving injected failures."""
        step = start_step
        end = start_step + n_steps
        metrics_log: List[dict] = []
        while step < end:
            try:
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch_fn(step))
                dt = time.perf_counter() - t0
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, step_time_s=dt)
                metrics_log.append(m)
                for h in hooks or []:
                    h(step, m)
                step += 1
                if step % self.save_every == 0:
                    self.ckpt.save(
                        step, state,
                        extras=self.extras_fn(step) if self.extras_fn else {})
            except Exception as e:  # noqa: BLE001 — the whole point
                self.restarts += 1
                self.restart_log.append((step, repr(e)))
                log.warning("step %d failed (%s); restart %d/%d",
                            step, e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                restored = self.ckpt.restore_latest(state)
                if restored is None:
                    # no checkpoint yet: replay from the beginning
                    step = start_step
                    continue
                step, state, _ = restored
        return state, step, metrics_log
