"""Measurement-farm daemon: serve wall-clock timings to remote tuners.

Runs a :class:`~repro.core.measure_service.MeasureServer` on this host —
the machine whose hardware the timings should reflect — and serves any
number of tuner clients (``launch/tune --farm HOST:PORT``, or
``make_backend("remote", addr=...)`` directly).  The default
``--measure pool`` wraps the warm pinned :class:`WorkerPool`, so client
batches parallelize across this host's cores and a hung schedule is
bounded by ``--task-timeout-s`` (the pool's hung-kill machinery) instead
of wedging the farm.

Fleet capacity is bounded and observable: ``--queue-limit`` caps the
central admission queue (beyond it clients get ``overloaded`` +
``retry_after_s`` and back off), ``--coalesce-requests`` /
``--coalesce-nests`` bound how much queued cross-client work folds into
one pool batch, and the ``status`` op reports queue depth / inflight /
served / per-client counters.  SIGTERM (and ``--max-requests``) drains:
stop accepting, finish queued + inflight work, answer stragglers
``shutting_down``, exit 0 — so a supervised farm restarts cleanly.

    PYTHONPATH=src python -m repro.launch.measure_farm \
        --addr 0.0.0.0:7461 --backend jax --measure pool

The first stdout line is ``[farm] listening on HOST:PORT ...`` (flushed),
so launchers and tests can scrape the bound port when ``--addr`` uses
port 0 (ephemeral).
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
from typing import Any, Dict, Optional

from repro.core.measure import MeasurementPolicy
from repro.core.measure_service import MeasureServer, parse_addr


def build_server(
    addr: str = "127.0.0.1:0",
    backend: str = "auto",
    measure: str = "pool",
    pool_workers: Optional[int] = None,
    task_timeout_s: Optional[float] = 120.0,
    repeats: Optional[int] = None,
    max_requests: Optional[int] = None,
    queue_limit: int = 32,
    coalesce_requests: int = 4,
    coalesce_nests: int = 64,
) -> MeasureServer:
    host, port = parse_addr(addr)
    kwargs: Dict[str, Any] = {"measure": measure}
    if measure == "pool":
        kwargs["pool_workers"] = pool_workers
        kwargs["pool_timeout_s"] = task_timeout_s
    if repeats is not None:
        kwargs["policy"] = MeasurementPolicy(
            repeats=repeats,
            max_repeats=max(repeats, MeasurementPolicy.max_repeats))
    return MeasureServer(host=host, port=port, backend=backend,
                         backend_kwargs=kwargs, max_requests=max_requests,
                         queue_limit=queue_limit,
                         coalesce_requests=coalesce_requests,
                         coalesce_nests=coalesce_nests)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="bind address (port 0 = ephemeral, printed on "
                         "the first stdout line)")
    ap.add_argument("--backend", default="auto",
                    help="executor doing the timing: numpy|jax|tpu|auto")
    ap.add_argument("--measure", default="pool", choices=("pool", "inproc"),
                    help="pool = parallelize batches across this host's "
                         "cores with hung-kill bounds (default)")
    ap.add_argument("--pool-workers", type=int, default=None)
    ap.add_argument("--task-timeout-s", type=float, default=120.0,
                    help="per-schedule hung-kill budget inside the pool")
    ap.add_argument("--repeats", type=int, default=None,
                    help="base best-of window (default: policy default)")
    ap.add_argument("--max-requests", type=int, default=None,
                    help="drain after N measure requests (tests/smoke)")
    ap.add_argument("--queue-limit", type=int, default=32,
                    help="admission queue bound; beyond it clients get "
                         "'overloaded' + retry_after_s (default 32)")
    ap.add_argument("--coalesce-requests", type=int, default=4,
                    help="max queued requests folded into one pool batch")
    ap.add_argument("--coalesce-nests", type=int, default=64,
                    help="max nests per coalesced pool batch")
    args = ap.parse_args(argv)

    server = build_server(
        addr=args.addr, backend=args.backend, measure=args.measure,
        pool_workers=args.pool_workers, task_timeout_s=args.task_timeout_s,
        repeats=args.repeats, max_requests=args.max_requests,
        queue_limit=args.queue_limit,
        coalesce_requests=args.coalesce_requests,
        coalesce_nests=args.coalesce_nests)

    def _on_sigterm(signum, frame):  # noqa: ARG001 - signal signature
        # drain, don't die: finish queued + inflight work, answer new
        # requests shutting_down, release serve_forever → exit 0
        print("[farm] SIGTERM: draining", flush=True)
        server.drain()

    signal.signal(signal.SIGTERM, _on_sigterm)

    print(f"[farm] listening on {server.addr} "
          f"backend={args.backend} measure={args.measure} "
          f"queue_limit={args.queue_limit} "
          f"hardware={server.hardware!r}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    print("[farm] stopped", json.dumps(server.stats()), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
