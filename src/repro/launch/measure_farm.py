"""Measurement-farm daemon: serve wall-clock timings to remote tuners.

Runs a :class:`~repro.core.measure_service.MeasureServer` on this host —
the machine whose hardware the timings should reflect — and serves any
number of tuner clients (``launch/tune --farm HOST:PORT``, or
``make_backend("remote", addr=...)`` directly).  The default
``--measure pool`` wraps the warm pinned :class:`WorkerPool`, so client
batches parallelize across this host's cores and a hung schedule is
bounded by ``--task-timeout-s`` (the pool's hung-kill machinery) instead
of wedging the farm.

    PYTHONPATH=src python -m repro.launch.measure_farm \
        --addr 0.0.0.0:7461 --backend jax --measure pool

The first stdout line is ``[farm] listening on HOST:PORT ...`` (flushed),
so launchers and tests can scrape the bound port when ``--addr`` uses
port 0 (ephemeral).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Optional

from repro.core.measure import MeasurementPolicy
from repro.core.measure_service import MeasureServer, parse_addr


def build_server(
    addr: str = "127.0.0.1:0",
    backend: str = "auto",
    measure: str = "pool",
    pool_workers: Optional[int] = None,
    task_timeout_s: Optional[float] = 120.0,
    repeats: Optional[int] = None,
    max_requests: Optional[int] = None,
) -> MeasureServer:
    host, port = parse_addr(addr)
    kwargs: Dict[str, Any] = {"measure": measure}
    if measure == "pool":
        kwargs["pool_workers"] = pool_workers
        kwargs["pool_timeout_s"] = task_timeout_s
    if repeats is not None:
        kwargs["policy"] = MeasurementPolicy(
            repeats=repeats,
            max_repeats=max(repeats, MeasurementPolicy.max_repeats))
    return MeasureServer(host=host, port=port, backend=backend,
                         backend_kwargs=kwargs, max_requests=max_requests)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="bind address (port 0 = ephemeral, printed on "
                         "the first stdout line)")
    ap.add_argument("--backend", default="auto",
                    help="executor doing the timing: numpy|jax|tpu|auto")
    ap.add_argument("--measure", default="pool", choices=("pool", "inproc"),
                    help="pool = parallelize batches across this host's "
                         "cores with hung-kill bounds (default)")
    ap.add_argument("--pool-workers", type=int, default=None)
    ap.add_argument("--task-timeout-s", type=float, default=120.0,
                    help="per-schedule hung-kill budget inside the pool")
    ap.add_argument("--repeats", type=int, default=None,
                    help="base best-of window (default: policy default)")
    ap.add_argument("--max-requests", type=int, default=None,
                    help="exit after N measure requests (tests/smoke)")
    args = ap.parse_args(argv)

    server = build_server(
        addr=args.addr, backend=args.backend, measure=args.measure,
        pool_workers=args.pool_workers, task_timeout_s=args.task_timeout_s,
        repeats=args.repeats, max_requests=args.max_requests)
    print(f"[farm] listening on {server.addr} "
          f"backend={args.backend} measure={args.measure} "
          f"hardware={server.hardware!r}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    print("[farm] stopped", json.dumps(server.stats()), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
