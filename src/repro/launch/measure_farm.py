"""Measurement-farm daemon: serve wall-clock timings to remote tuners.

Runs a :class:`~repro.core.measure_service.MeasureServer` on this host —
the machine whose hardware the timings should reflect — and serves any
number of tuner clients (``launch/tune --farm HOST:PORT``, or
``make_backend("remote", addr=...)`` directly).  The default
``--measure pool`` wraps the warm pinned :class:`WorkerPool`, so client
batches parallelize across this host's cores and a hung schedule is
bounded by ``--task-timeout-s`` (the pool's hung-kill machinery) instead
of wedging the farm.

Fleet capacity is bounded and observable: ``--queue-limit`` caps the
central admission queue (beyond it clients get ``overloaded`` +
``retry_after_s`` and back off), ``--coalesce-requests`` /
``--coalesce-nests`` bound how much queued cross-client work folds into
one pool batch, and the ``status`` op reports queue depth / inflight /
served / per-client counters.  SIGTERM (and ``--max-requests``) drains:
stop accepting, finish queued + inflight work, answer stragglers
``shutting_down``, exit 0 — so a supervised farm restarts cleanly.

    PYTHONPATH=src python -m repro.launch.measure_farm \
        --addr 0.0.0.0:7461 --backend jax --measure pool

The first stdout line is ``[farm] listening on HOST:PORT ...`` (flushed),
so launchers and tests can scrape the bound port when ``--addr`` uses
port 0 (ephemeral).

The daemon doubles as its own client for operations checks:
``--status`` connects to a *running* farm, sends the ``status`` op and
pretty-prints the fleet view (queue depth/peak, inflight, ticket
pipeline, per-client served counts, drain state) — the thing an operator
looks at before deciding whether a farm can take another ``--fleet N``
of tuner clients.
"""
from __future__ import annotations

import argparse
import json
import signal
import socket
import sys
from typing import Any, Dict, Optional

from repro.core.measure import MeasurementPolicy
from repro.core.measure_service import (MeasureServer, parse_addr,
                                        recv_frame, send_frame)


def build_server(
    addr: str = "127.0.0.1:0",
    backend: str = "auto",
    measure: str = "pool",
    pool_workers: Optional[int] = None,
    task_timeout_s: Optional[float] = 120.0,
    repeats: Optional[int] = None,
    max_requests: Optional[int] = None,
    queue_limit: int = 32,
    coalesce_requests: int = 4,
    coalesce_nests: int = 64,
    coalesce_window_s: float = 0.0,
) -> MeasureServer:
    host, port = parse_addr(addr)
    kwargs: Dict[str, Any] = {"measure": measure}
    if measure == "pool":
        kwargs["pool_workers"] = pool_workers
        kwargs["pool_timeout_s"] = task_timeout_s
    if repeats is not None:
        kwargs["policy"] = MeasurementPolicy(
            repeats=repeats,
            max_repeats=max(repeats, MeasurementPolicy.max_repeats))
    return MeasureServer(host=host, port=port, backend=backend,
                         backend_kwargs=kwargs, max_requests=max_requests,
                         queue_limit=queue_limit,
                         coalesce_requests=coalesce_requests,
                         coalesce_nests=coalesce_nests,
                         coalesce_window_s=coalesce_window_s)


def farm_status(addr: str, timeout_s: float = 5.0) -> Dict[str, Any]:
    """Connect to a running farm and return its ``status`` op reply."""
    host, port = parse_addr(addr)
    with socket.create_connection((host, port), timeout=timeout_s) as sock:
        send_frame(sock, {"op": "status", "id": 0})
        reply = recv_frame(sock)
    if not isinstance(reply, dict) or not reply.get("ok"):
        raise ConnectionError(f"farm at {addr} returned {reply!r}")
    return reply


def print_status(addr: str, timeout_s: float = 5.0) -> int:
    """``--status``: pretty-print a running farm's fleet view."""
    try:
        st = farm_status(addr, timeout_s=timeout_s)
    except OSError as e:
        print(f"[farm] status: cannot reach {addr}: {e}", file=sys.stderr)
        return 1
    state = "draining" if st.get("draining") else "serving"
    print(f"[farm] {st.get('addr', addr)}  {state}  "
          f"backend={st.get('backend')}  hardware={st.get('hardware')!r}")
    print(f"  queue     depth={st.get('queue_depth')}/"
          f"{st.get('queue_limit')}  peak={st.get('queue_depth_peak')}  "
          f"deferred_clients={st.get('deferred_clients')}")
    print(f"  inflight  requests={st.get('inflight_requests')}  "
          f"nests={st.get('inflight_nests')}")
    print(f"  served    requests={st.get('served_requests')}  "
          f"nests={st.get('served_nests')}  "
          f"pool_batches={st.get('pool_batches')}  "
          f"coalesced={st.get('coalesced_batches')}")
    print(f"  rejected  overload={st.get('rejected_overload')}  "
          f"shutdown={st.get('rejected_shutdown')}  "
          f"errors={st.get('errors')}")
    print(f"  tickets   submitted={st.get('tickets_submitted')}  "
          f"deduped={st.get('tickets_deduped')}  "
          f"collected={st.get('tickets_collected')}  "
          f"acked={st.get('tickets_acked')}  "
          f"expired={st.get('tickets_expired')}  "
          f"outstanding={st.get('tickets_outstanding')}  "
          f"parked={st.get('tickets_parked')}")
    spn = st.get("service_s_per_nest")
    print(f"  pace      service_s_per_nest="
          f"{spn if spn is not None else 'n/a'}")
    clients = st.get("clients") or {}
    if clients:
        print("  clients   (nests served)")
        for name, n in sorted(clients.items(), key=lambda kv: -kv[1]):
            print(f"    {name}: {n}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="bind address (port 0 = ephemeral, printed on "
                         "the first stdout line); with --status, the "
                         "running farm to query")
    ap.add_argument("--status", action="store_true",
                    help="don't serve: connect to the farm at --addr, "
                         "pretty-print its status op (queue depth/peak, "
                         "inflight, ticket pipeline, per-client counts, "
                         "drain state) and exit")
    ap.add_argument("--backend", default="auto",
                    help="executor doing the timing: numpy|jax|tpu|auto")
    ap.add_argument("--measure", default="pool", choices=("pool", "inproc"),
                    help="pool = parallelize batches across this host's "
                         "cores with hung-kill bounds (default)")
    ap.add_argument("--pool-workers", type=int, default=None)
    ap.add_argument("--task-timeout-s", type=float, default=120.0,
                    help="per-schedule hung-kill budget inside the pool")
    ap.add_argument("--repeats", type=int, default=None,
                    help="base best-of window (default: policy default)")
    ap.add_argument("--max-requests", type=int, default=None,
                    help="drain after N measure requests (tests/smoke)")
    ap.add_argument("--queue-limit", type=int, default=32,
                    help="admission queue bound; beyond it clients get "
                         "'overloaded' + retry_after_s (default 32)")
    ap.add_argument("--coalesce-requests", type=int, default=4,
                    help="max queued requests folded into one pool batch")
    ap.add_argument("--coalesce-nests", type=int, default=64,
                    help="max nests per coalesced pool batch")
    ap.add_argument("--coalesce-window-s", type=float, default=0.0,
                    help="batch-forming linger: hold an under-filled "
                         "batch open this long so near-simultaneous "
                         "submits from a pipelined fleet coalesce "
                         "(default 0 = dispatch eagerly)")
    args = ap.parse_args(argv)

    if args.status:
        return print_status(args.addr)

    server = build_server(
        addr=args.addr, backend=args.backend, measure=args.measure,
        pool_workers=args.pool_workers, task_timeout_s=args.task_timeout_s,
        repeats=args.repeats, max_requests=args.max_requests,
        queue_limit=args.queue_limit,
        coalesce_requests=args.coalesce_requests,
        coalesce_nests=args.coalesce_nests,
        coalesce_window_s=args.coalesce_window_s)

    def _on_sigterm(signum, frame):  # noqa: ARG001 - signal signature
        # drain, don't die: finish queued + inflight work, answer new
        # requests shutting_down, release serve_forever → exit 0
        print("[farm] SIGTERM: draining", flush=True)
        server.drain()

    signal.signal(signal.SIGTERM, _on_sigterm)

    print(f"[farm] listening on {server.addr} "
          f"backend={args.backend} measure={args.measure} "
          f"queue_limit={args.queue_limit} "
          f"hardware={server.hardware!r}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    print("[farm] stopped", json.dumps(server.stats()), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
