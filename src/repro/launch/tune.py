"""Offline tuning pre-pass: harvest a model's contractions, tune, persist.

This is the "tune once, off the request path" half of schedule serving
(AutoTVM's TopHub pattern): lower the model config's serving steps exactly
as ``launch/serve`` jits them, parse the executed dot contractions out of
the optimized HLO (``analysis.hlo_parse.harvest_dots`` — occurrence counts
ride the scan-over-layers trip counts), dedup by structural signature, and
spend the tuning budget proportionally to each contraction's executed-FLOP
share so the roofline-dominant shapes get tuned hardest.  Best schedules
land in a :class:`~repro.core.registry.ScheduleRegistry` table that
``launch/serve --registry`` consumes at model-compile time.

    PYTHONPATH=src python -m repro.launch.tune --arch musicgen-large \
        --registry /tmp/musicgen.json --budget-s 4
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs import ShapeCell, get_config, input_specs
from repro.core.backend import make_backend
from repro.core.loop_ir import Contraction, matmul_benchmark
from repro.core.registry import ScheduleRegistry
from repro.core.tuner import LoopTuner


def harvest_model(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 24,
    max_len: int = 64,
    kinds: Sequence[str] = ("decode", "prefill"),
) -> List[Dict[str, Any]]:
    """Executed dot contractions of a model's serving steps.

    Lowers + compiles the decode (and prefill) step functions with
    ShapeDtypeStruct stand-ins — zero allocation, same jit the server
    builds — and returns :func:`harvest_dots` records aggregated across
    step kinds, sorted by executed-FLOP share.  ``batch``/``prompt_len``/
    ``max_len`` must match the serving shapes for the harvested workload
    keys to be the ones the server looks up.
    """
    import jax

    from repro.analysis.hlo_parse import harvest_dots
    from repro.models import steps as S
    from repro.models import transformer as T

    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    agg: Dict[Tuple[int, int, int, str], Dict[str, float]] = {}
    for kind in kinds:
        if kind == "decode":
            specs = input_specs(cfg, ShapeCell("serve", max_len, batch,
                                               "decode"))
            fn = jax.jit(S.make_decode_step(cfg))
            lowered = fn.lower(params, specs["batch"], specs["caches"],
                               specs["cache_len"])
        elif kind == "prefill":
            specs = input_specs(cfg, ShapeCell("prefill", prompt_len, batch,
                                               "prefill"))
            fn = jax.jit(S.make_prefill_step(cfg, max_len=max_len))
            lowered = fn.lower(params, specs["batch"])
        else:
            raise ValueError(f"unknown step kind {kind!r}")
        for rec in harvest_dots(lowered.compile().as_text()):
            # fold batch dims into m: a batched GEMM tunes as (b*m, k, n)
            key = (rec["batch"] * rec["m"], rec["k"], rec["n"], rec["dtype"])
            slot = agg.setdefault(key, {"count": 0.0, "flops": 0.0})
            slot["count"] += rec["count"]
            slot["flops"] += rec["flops"]
    total = sum(s["flops"] for s in agg.values()) or 1.0
    out = [
        {"m": m, "k": k, "n": n, "dtype": dt, "count": s["count"],
         "flops": s["flops"], "flop_share": s["flops"] / total}
        for (m, k, n, dt), s in agg.items()
    ]
    out.sort(key=lambda r: -r["flops"])
    return out


def tune_model(
    cfg_or_arch,
    *,
    registry: Optional[ScheduleRegistry] = None,
    registry_path: Optional[str] = None,
    tuner: Optional[LoopTuner] = None,
    checkpoint: Optional[str] = None,
    backend: str = "tpu",
    policy: str = "search",
    budget_s: float = 4.0,
    eval_budget: Optional[int] = None,
    max_contractions: int = 12,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 24,
    max_len: int = 64,
    kinds: Sequence[str] = ("decode", "prefill"),
    kernel_cache: Optional[str] = None,
    farm: Optional[str] = None,
) -> Dict[str, Any]:
    """Tune every contraction a model config lowers to; persist the table.

    ``budget_s`` (and ``eval_budget``, when given) are *totals* for the
    whole model, split across the deduped contractions by executed-FLOP
    share — the contraction that dominates the roofline gets the budget.
    ``kernel_cache`` names the persistent compiled-kernel store dir (jax
    backends): re-tuning the same model loads yesterday's executables
    instead of re-tracing them.  Returns a report dict (harvested/tuned
    counts, per-entry summaries, coverage of the executed FLOPs).
    """
    t0 = time.perf_counter()
    cfg = get_config(cfg_or_arch) if isinstance(cfg_or_arch, str) else cfg_or_arch
    if smoke and not cfg.name.endswith("-smoke"):
        cfg = cfg.smoke()
    if registry is None:
        registry = ScheduleRegistry(registry_path)
    if tuner is None:
        # --farm: timings come from a remote measurement farm; ``backend``
        # becomes the local fallback the client degrades to if the farm is
        # unreachable (a tune is never failed by the farm)
        tune_backend = (make_backend("remote", addr=farm, fallback=backend)
                        if farm is not None else backend)
        if checkpoint is not None:
            tuner = LoopTuner.from_checkpoint(checkpoint, backend=tune_backend,
                                              registry=registry,
                                              cache_dir=kernel_cache)
        else:
            tuner = LoopTuner(policy=policy, backend=tune_backend,
                              registry=registry, cache_dir=kernel_cache)

    records = harvest_model(cfg, batch=batch, prompt_len=prompt_len,
                            max_len=max_len, kinds=kinds)
    kept = records[:max_contractions]
    share_kept = sum(r["flop_share"] for r in kept)
    benches: List[Contraction] = []
    weights: List[float] = []
    dtypes: List[str] = []
    for r in kept:
        benches.append(matmul_benchmark(r["m"], r["k"], r["n"]))
        weights.append(r["flop_share"] / share_kept if share_kept else 1.0)
        dtypes.append(r["dtype"])

    entries = tuner.tune_many(
        benches, kernel="mm", weights=weights, dtypes=dtypes,
        budget_s=budget_s, eval_budget=eval_budget)

    if registry_path:
        registry.save(registry_path)
    elif registry.path:
        registry.save()
    compile_stats = getattr(tuner.backend, "compile_stats", None)
    farm_stats = getattr(tuner.backend, "farm_stats", None)
    return {
        "arch": cfg.name,
        "kinds": list(kinds),
        "shapes": {"batch": batch, "prompt_len": prompt_len,
                   "max_len": max_len},
        "n_harvested": len(records),
        "n_tuned": len(entries),
        "flop_share_covered": share_kept,
        "registry_size": len(registry),
        "registry_path": registry_path or registry.path,
        "kernel_cache": kernel_cache,
        "compile": compile_stats() if compile_stats is not None else None,
        "farm": farm_stats() if farm_stats is not None else None,
        "tune_time_s": round(time.perf_counter() - t0, 2),
        "contractions": [
            {"m": r["m"], "k": r["k"], "n": r["n"], "dtype": r["dtype"],
             "count": r["count"], "flop_share": round(r["flop_share"], 4),
             "gflops": e.get("gflops"),
             "base_gflops": e.get("base_gflops")}
            for r, e in zip(kept, entries)
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--registry", required=True, help="registry JSON path")
    ap.add_argument("--full", action="store_true",
                    help="published config (fleet scale); default smoke")
    ap.add_argument("--checkpoint", default=None,
                    help="trained policy checkpoint (default: search)")
    ap.add_argument("--backend", default="tpu")
    ap.add_argument("--budget-s", type=float, default=4.0)
    ap.add_argument("--eval-budget", type=int, default=None)
    ap.add_argument("--max-contractions", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--kernel-cache", default=None,
                    help="persistent compiled-kernel store dir (jax "
                         "backends; default: <registry>.kernels; 'off' "
                         "disables)")
    ap.add_argument("--farm", default=None, metavar="HOST:PORT",
                    help="measure on a remote farm (repro.launch."
                         "measure_farm); --backend becomes the local "
                         "fallback if the farm is unreachable")
    args = ap.parse_args(argv)

    # the kernel store lives beside the registry by default: the artifacts
    # and the schedules they serve travel (and get wiped) together
    kernel_cache: Optional[str]
    if args.kernel_cache == "off":
        kernel_cache = None
    elif args.kernel_cache is None:
        kernel_cache = args.registry + ".kernels"
    else:
        kernel_cache = args.kernel_cache

    report = tune_model(
        args.arch, registry_path=args.registry, checkpoint=args.checkpoint,
        backend=args.backend, budget_s=args.budget_s,
        eval_budget=args.eval_budget, max_contractions=args.max_contractions,
        smoke=not args.full, batch=args.batch, prompt_len=args.prompt_len,
        max_len=args.max_len, kernel_cache=kernel_cache, farm=args.farm)
    print("[tune]", json.dumps(report, indent=1), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
