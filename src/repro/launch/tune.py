"""Offline tuning pre-pass: harvest a model's contractions, tune, persist.

This is the "tune once, off the request path" half of schedule serving
(AutoTVM's TopHub pattern): lower the model config's serving steps exactly
as ``launch/serve`` jits them, parse the executed dot contractions out of
the optimized HLO (``analysis.hlo_parse.harvest_dots`` — occurrence counts
ride the scan-over-layers trip counts), dedup by structural signature, and
spend the tuning budget proportionally to each contraction's executed-FLOP
share so the roofline-dominant shapes get tuned hardest.  Best schedules
land in a :class:`~repro.core.registry.ScheduleRegistry` table that
``launch/serve --registry`` consumes at model-compile time.

    PYTHONPATH=src python -m repro.launch.tune --arch musicgen-large \
        --registry /tmp/musicgen.json --budget-s 4

Tuning is **crash-resumable**: per-contraction results append to a JSONL
journal (default ``<registry>.journal.jsonl``) the moment each contraction
finishes, and the registry flushes (lock-merge-save) at the same
granularity — so a client kill, farm death, or host reboot loses at most
the contraction in flight.  ``--resume`` reloads the journal and re-tunes
only the unfinished contractions.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs import ShapeCell, get_config, input_specs
from repro.core.backend import make_backend
from repro.core.loop_ir import matmul_benchmark
from repro.core.registry import ScheduleRegistry
from repro.core.rl_common import epsilon_ladder
from repro.core.tuner import LoopTuner


class TuneJournal:
    """Append-only JSONL ledger of per-contraction tune results.

    One line per finished contraction: ``{"key": ..., "entry": {...}}``,
    flushed + fsynced on append so a SIGKILL after contraction *i* leaves
    lines 0..i durable.  :meth:`load` tolerates a torn trailing line (the
    one write a crash can interrupt) by ignoring it; torn lines *elsewhere*
    are warned about and skipped — progress is best-effort recovered, never
    corrupted.  Keys are workload signatures (:meth:`key_of`), so a resume
    matches by what was tuned, not by position.
    """

    def __init__(self, path: str):
        self.path = path

    @staticmethod
    def key_of(m: int, k: int, n: int, dtype: str = "float32") -> str:
        return f"mm:{m}x{k}x{n}:{dtype}"

    def load(self) -> Dict[str, Dict[str, Any]]:
        done: Dict[str, Dict[str, Any]] = {}
        if not os.path.exists(self.path):
            return done
        with open(self.path) as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
                done[str(rec["key"])] = dict(rec["entry"])
            except (ValueError, KeyError, TypeError):
                if i == len(lines) - 1:
                    continue  # torn tail: the interrupted final append
                warnings.warn(
                    f"tune journal {self.path}: skipping corrupt line "
                    f"{i + 1} (not the tail — was the file edited?)",
                    stacklevel=2)
        return done

    def append(self, key: str, entry: Dict[str, Any]) -> None:
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        line = json.dumps({"key": key, "entry": entry}, default=str)
        # one write() call per line on a fresh O_APPEND handle, so fleet
        # clients appending concurrently never interleave mid-line
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def reset(self) -> None:
        """Start a fresh session (non-resume runs must not inherit a stale
        journal, or a later --resume would skip work it never did)."""
        if os.path.exists(self.path):
            os.unlink(self.path)


def harvest_model(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 24,
    max_len: int = 64,
    kinds: Sequence[str] = ("decode", "prefill"),
) -> List[Dict[str, Any]]:
    """Executed dot contractions of a model's serving steps.

    Lowers + compiles the decode (and prefill) step functions with
    ShapeDtypeStruct stand-ins — zero allocation, same jit the server
    builds — and returns :func:`harvest_dots` records aggregated across
    step kinds, sorted by executed-FLOP share.  ``batch``/``prompt_len``/
    ``max_len`` must match the serving shapes for the harvested workload
    keys to be the ones the server looks up.
    """
    import jax

    from repro.analysis.hlo_parse import harvest_dots
    from repro.models import steps as S
    from repro.models import transformer as T

    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    agg: Dict[Tuple[int, int, int, str], Dict[str, float]] = {}
    for kind in kinds:
        if kind == "decode":
            specs = input_specs(cfg, ShapeCell("serve", max_len, batch,
                                               "decode"))
            fn = jax.jit(S.make_decode_step(cfg))
            lowered = fn.lower(params, specs["batch"], specs["caches"],
                               specs["cache_len"])
        elif kind == "prefill":
            specs = input_specs(cfg, ShapeCell("prefill", prompt_len, batch,
                                               "prefill"))
            fn = jax.jit(S.make_prefill_step(cfg, max_len=max_len))
            lowered = fn.lower(params, specs["batch"])
        else:
            raise ValueError(f"unknown step kind {kind!r}")
        for rec in harvest_dots(lowered.compile().as_text()):
            # fold batch dims into m: a batched GEMM tunes as (b*m, k, n)
            key = (rec["batch"] * rec["m"], rec["k"], rec["n"], rec["dtype"])
            slot = agg.setdefault(key, {"count": 0.0, "flops": 0.0})
            slot["count"] += rec["count"]
            slot["flops"] += rec["flops"]
    total = sum(s["flops"] for s in agg.values()) or 1.0
    out = [
        {"m": m, "k": k, "n": n, "dtype": dt, "count": s["count"],
         "flops": s["flops"], "flop_share": s["flops"] / total}
        for (m, k, n, dt), s in agg.items()
    ]
    out.sort(key=lambda r: -r["flops"])
    return out


def tune_records(
    kept: Sequence[Dict[str, Any]],
    *,
    tuner: LoopTuner,
    registry: ScheduleRegistry,
    registry_path: Optional[str] = None,
    budget_s: float = 4.0,
    eval_budget: Optional[int] = None,
    journal: Optional[TuneJournal] = None,
    resume: bool = False,
) -> Tuple[List[Dict[str, Any]], int]:
    """Tune harvested contraction records with journaled checkpoints.

    Each record needs ``m/k/n/dtype`` and ``flop_share`` (budget weight).
    As each contraction finishes, its entry appends to ``journal`` and the
    registry flushes (lock-merge-save) — crash granularity is one
    contraction.  With ``resume``, records whose journal key is already
    present are skipped (their journaled entries returned in place) and
    the remaining budget is scaled to the remaining FLOP share.  Returns
    ``(entries aligned with kept, n_skipped)``.
    """
    kept = list(kept)
    keys = [TuneJournal.key_of(r["m"], r["k"], r["n"], r["dtype"])
            for r in kept]
    done: Dict[str, Dict[str, Any]] = {}
    if journal is not None:
        if resume:
            done = journal.load()
        else:
            journal.reset()
    todo = [i for i, k in enumerate(keys) if k not in done]
    entries: List[Optional[Dict[str, Any]]] = [
        None if k not in done else dict(done[k], resumed=True)
        for k in keys]
    if not todo:
        return [e for e in entries if e is not None], len(kept)

    total_share = sum(r["flop_share"] for r in kept) or 1.0
    todo_share = sum(kept[i]["flop_share"] for i in todo) or 1.0
    flush_path = registry_path or registry.path

    def on_entry(j: int, entry: Dict[str, Any]) -> None:
        i = todo[j]
        entries[i] = entry
        if journal is not None:
            journal.append(keys[i], entry)
        # flush, not save: concurrent fleet shards (and a farm-side merge)
        # must not lose each other's records
        if flush_path:
            registry.flush(flush_path)

    tuner.tune_many(
        [matmul_benchmark(kept[i]["m"], kept[i]["k"], kept[i]["n"])
         for i in todo],
        kernel="mm",
        weights=[kept[i]["flop_share"] / todo_share for i in todo],
        dtypes=[kept[i]["dtype"] for i in todo],
        budget_s=budget_s * (todo_share / total_share),
        eval_budget=(max(len(todo),
                         int(round(eval_budget * todo_share / total_share)))
                     if eval_budget is not None else None),
        on_entry=on_entry)
    return [e for e in entries if e is not None], len(kept) - len(todo)


def tune_records_fleet(
    kept: Sequence[Dict[str, Any]],
    *,
    n_clients: int,
    farm: str,
    backend: str = "tpu",
    policy: str = "search",
    checkpoint: Optional[str] = None,
    registry_path: Optional[str] = None,
    budget_s: float = 4.0,
    eval_budget: Optional[int] = None,
    journal: Optional[TuneJournal] = None,
    resume: bool = False,
    kernel_cache: Optional[str] = None,
) -> Tuple[List[Dict[str, Any]], int, List[Dict[str, Any]]]:
    """``--fleet N``: N concurrent tuner clients against one farm.

    The Ape-X scale-out shape applied to tuning: just as Ape-X runs an
    ε-ladder of actors against one learner, the fleet runs N tuner clients
    (each its own :class:`LoopTuner` + pipelined farm connection, ranked on
    the same ladder for identity/telemetry) against one measurement farm.
    Contractions shard round-robin across clients, so every client keeps
    its own pipeline full — frontier generation and surrogate ranking on
    the client overlapping ticketed measurement on the farm — and the
    farm's fair queue interleaves their batches.

    Crash safety is the single-client story shared: all clients append to
    one :class:`TuneJournal` (line-atomic, lock-serialized) and flush
    per-client :class:`ScheduleRegistry` instances to the same path
    (flock-merged), so a kill loses at most one contraction per client.
    Budget semantics are unchanged — ``budget_s`` is the same *total* a
    single client would spend, so the fleet finishes ~N× sooner rather
    than spending N× more.

    Returns ``(entries aligned with kept, n_skipped, per-client reports)``.
    """
    kept = list(kept)
    keys = [TuneJournal.key_of(r["m"], r["k"], r["n"], r["dtype"])
            for r in kept]
    done: Dict[str, Dict[str, Any]] = {}
    if journal is not None:
        if resume:
            done = journal.load()
        else:
            journal.reset()
    todo = [i for i, k in enumerate(keys) if k not in done]
    entries: List[Optional[Dict[str, Any]]] = [
        None if k not in done else dict(done[k], resumed=True)
        for k in keys]
    if not todo:
        return [e for e in entries if e is not None], len(kept), []

    total_share = sum(r["flop_share"] for r in kept) or 1.0
    shards = [todo[c::n_clients] for c in range(n_clients)]
    shards = [s for s in shards if s]
    eps = epsilon_ladder(max(len(shards), 1))
    lock = threading.Lock()
    client_reports: List[Optional[Dict[str, Any]]] = [None] * len(shards)
    errors: List[BaseException] = []

    def run_client(c: int, shard: List[int]) -> None:
        t0 = time.perf_counter()
        # per-client farm connection: its own fair-queue identity, its own
        # pipelined submit/collect window, its own degradation state
        be = make_backend("remote", addr=farm, fallback=backend,
                          client_id=f"tune-{c}")
        registry = ScheduleRegistry(registry_path)
        if checkpoint is not None:
            tuner = LoopTuner.from_checkpoint(checkpoint, backend=be,
                                              registry=registry,
                                              cache_dir=kernel_cache)
        else:
            tuner = LoopTuner(policy=policy, backend=be, registry=registry,
                              cache_dir=kernel_cache)
        shard_share = sum(kept[i]["flop_share"] for i in shard) or 1.0

        def on_entry(j: int, entry: Dict[str, Any]) -> None:
            i = shard[j]
            with lock:
                entries[i] = entry
                if journal is not None:
                    journal.append(keys[i], entry)
            if registry_path:
                registry.flush(registry_path)

        try:
            tuner.tune_many(
                [matmul_benchmark(kept[i]["m"], kept[i]["k"], kept[i]["n"])
                 for i in shard],
                kernel="mm",
                weights=[kept[i]["flop_share"] / shard_share for i in shard],
                dtypes=[kept[i]["dtype"] for i in shard],
                budget_s=budget_s * (shard_share / total_share),
                eval_budget=(max(len(shard),
                                 int(round(eval_budget * shard_share
                                           / total_share)))
                             if eval_budget is not None else None),
                on_entry=on_entry)
            client_reports[c] = {
                "client": be.client_id,
                "eps": round(float(eps[c]), 4),
                "n_tuned": len(shard),
                "wall_s": round(time.perf_counter() - t0, 3),
                "farm": be.farm_stats(),
            }
        except BaseException as e:  # surfaced to the caller, not swallowed
            with lock:
                errors.append(e)
        finally:
            be.close()

    threads = [threading.Thread(target=run_client, args=(c, shard),
                                name=f"tune-fleet-{c}", daemon=True)
               for c, shard in enumerate(shards)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return ([e for e in entries if e is not None], len(kept) - len(todo),
            [r for r in client_reports if r is not None])


def tune_model(
    cfg_or_arch,
    *,
    registry: Optional[ScheduleRegistry] = None,
    registry_path: Optional[str] = None,
    tuner: Optional[LoopTuner] = None,
    checkpoint: Optional[str] = None,
    backend: str = "tpu",
    policy: str = "search",
    budget_s: float = 4.0,
    eval_budget: Optional[int] = None,
    max_contractions: int = 12,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 24,
    max_len: int = 64,
    kinds: Sequence[str] = ("decode", "prefill"),
    kernel_cache: Optional[str] = None,
    farm: Optional[str] = None,
    fleet: int = 1,
    journal_path: Optional[str] = None,
    resume: bool = False,
) -> Dict[str, Any]:
    """Tune every contraction a model config lowers to; persist the table.

    ``budget_s`` (and ``eval_budget``, when given) are *totals* for the
    whole model, split across the deduped contractions by executed-FLOP
    share — the contraction that dominates the roofline gets the budget.
    ``kernel_cache`` names the persistent compiled-kernel store dir (jax
    backends): re-tuning the same model loads yesterday's executables
    instead of re-tracing them.  Returns a report dict (harvested/tuned
    counts, per-entry summaries, coverage of the executed FLOPs).
    """
    t0 = time.perf_counter()
    cfg = get_config(cfg_or_arch) if isinstance(cfg_or_arch, str) else cfg_or_arch
    if smoke and not cfg.name.endswith("-smoke"):
        cfg = cfg.smoke()
    if fleet > 1 and (farm is None or tuner is not None):
        raise ValueError("--fleet N needs --farm (N clients share one "
                         "measurement farm) and builds its own per-client "
                         "tuners")
    if registry is None:
        registry = ScheduleRegistry(registry_path)
    if tuner is None and fleet <= 1:
        # --farm: timings come from a remote measurement farm; ``backend``
        # becomes the local fallback the client degrades to if the farm is
        # unreachable (a tune is never failed by the farm)
        tune_backend = (make_backend("remote", addr=farm, fallback=backend)
                        if farm is not None else backend)
        if checkpoint is not None:
            tuner = LoopTuner.from_checkpoint(checkpoint, backend=tune_backend,
                                              registry=registry,
                                              cache_dir=kernel_cache)
        else:
            tuner = LoopTuner(policy=policy, backend=tune_backend,
                              registry=registry, cache_dir=kernel_cache)

    records = harvest_model(cfg, batch=batch, prompt_len=prompt_len,
                            max_len=max_len, kinds=kinds)
    kept = records[:max_contractions]
    share_kept = sum(r["flop_share"] for r in kept)

    journal = TuneJournal(journal_path) if journal_path else None
    fleet_report: Optional[Dict[str, Any]] = None
    if fleet > 1:
        entries, n_skipped, clients = tune_records_fleet(
            kept, n_clients=fleet, farm=farm, backend=backend,
            policy=policy, checkpoint=checkpoint,
            registry_path=registry_path, budget_s=budget_s,
            eval_budget=eval_budget, journal=journal, resume=resume,
            kernel_cache=kernel_cache)
        # fleet-mode flushes land per client; re-read so report counts and
        # a final save reflect the merged table
        if registry_path and os.path.exists(registry_path):
            registry = ScheduleRegistry(registry_path)
        fleet_report = {
            "n_clients": fleet,
            "clients": clients,
            # farm totals across the fleet: the aggregate pipelining view
            "tickets_submitted": sum(
                c["farm"].get("tickets_submitted", 0) for c in clients),
            "tickets_collected": sum(
                c["farm"].get("tickets_collected", 0) for c in clients),
            "tickets_resubmitted": sum(
                c["farm"].get("tickets_resubmitted", 0) for c in clients),
        }
    else:
        entries, n_skipped = tune_records(
            kept, tuner=tuner, registry=registry, registry_path=registry_path,
            budget_s=budget_s, eval_budget=eval_budget,
            journal=journal, resume=resume)

    path = registry_path or registry.path
    if path:
        registry.flush(path)
    tb = tuner.backend if tuner is not None else None
    compile_stats = getattr(tb, "compile_stats", None)
    farm_stats = getattr(tb, "farm_stats", None)
    return {
        "arch": cfg.name,
        "kinds": list(kinds),
        "shapes": {"batch": batch, "prompt_len": prompt_len,
                   "max_len": max_len},
        "n_harvested": len(records),
        "n_tuned": len(entries),
        "n_skipped": n_skipped,
        "resumed": bool(resume),
        "journal": journal_path,
        "flop_share_covered": share_kept,
        "registry_size": len(registry),
        "registry_path": registry_path or registry.path,
        "kernel_cache": kernel_cache,
        "compile": compile_stats() if compile_stats is not None else None,
        "farm": farm_stats() if farm_stats is not None else None,
        "fleet": fleet_report,
        "tune_time_s": round(time.perf_counter() - t0, 2),
        "contractions": [
            {"m": r["m"], "k": r["k"], "n": r["n"], "dtype": r["dtype"],
             "count": r["count"], "flop_share": round(r["flop_share"], 4),
             "gflops": e.get("gflops"),
             "base_gflops": e.get("base_gflops"),
             "resumed": bool(e.get("resumed", False))}
            for r, e in zip(kept, entries)
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--registry", required=True, help="registry JSON path")
    ap.add_argument("--full", action="store_true",
                    help="published config (fleet scale); default smoke")
    ap.add_argument("--checkpoint", default=None,
                    help="trained policy checkpoint (default: search)")
    ap.add_argument("--backend", default="tpu")
    ap.add_argument("--budget-s", type=float, default=4.0)
    ap.add_argument("--eval-budget", type=int, default=None)
    ap.add_argument("--max-contractions", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--kernel-cache", default=None,
                    help="persistent compiled-kernel store dir (jax "
                         "backends; default: <registry>.kernels; 'off' "
                         "disables)")
    ap.add_argument("--farm", default=None, metavar="HOST:PORT",
                    help="measure on a remote farm (repro.launch."
                         "measure_farm); --backend becomes the local "
                         "fallback if the farm is unreachable")
    ap.add_argument("--fleet", type=int, default=1, metavar="N",
                    help="run N concurrent tuner clients against the one "
                         "--farm (contractions shard round-robin; each "
                         "client pipelines ticketed measurements on its "
                         "own connection; requires --farm)")
    ap.add_argument("--journal", default=None,
                    help="per-contraction JSONL progress ledger (default: "
                         "<registry>.journal.jsonl; 'off' disables)")
    ap.add_argument("--resume", action="store_true",
                    help="skip contractions already in the journal (after "
                         "a crash/kill: re-tunes only unfinished work)")
    args = ap.parse_args(argv)

    # the kernel store lives beside the registry by default: the artifacts
    # and the schedules they serve travel (and get wiped) together
    kernel_cache: Optional[str]
    if args.kernel_cache == "off":
        kernel_cache = None
    elif args.kernel_cache is None:
        kernel_cache = args.registry + ".kernels"
    else:
        kernel_cache = args.kernel_cache

    # the journal lives beside the registry by default, same reasoning as
    # the kernel store: session state and its output travel together
    journal_path: Optional[str]
    if args.journal == "off":
        journal_path = None
    elif args.journal is None:
        journal_path = args.registry + ".journal.jsonl"
    else:
        journal_path = args.journal

    report = tune_model(
        args.arch, registry_path=args.registry, checkpoint=args.checkpoint,
        backend=args.backend, budget_s=args.budget_s,
        eval_budget=args.eval_budget, max_contractions=args.max_contractions,
        smoke=not args.full, batch=args.batch, prompt_len=args.prompt_len,
        max_len=args.max_len, kernel_cache=kernel_cache, farm=args.farm,
        fleet=args.fleet, journal_path=journal_path, resume=args.resume)
    print("[tune]", json.dumps(report, indent=1), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
