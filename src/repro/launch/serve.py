"""Batched serving launcher: continuous-batching decode loop.

Implements the serving shape cells' step for real: a request pool feeds a
fixed-size decode batch; finished requests are retired and their slots
refilled (continuous batching), prefill runs per-admission, and the decode
step is the jitted ``serve_step`` the dry-run lowers for decode_32k /
long_500k.

``--registry PATH`` serves tuned schedules: the prefill/decode step bodies
trace under ``kernels.ops.serving``, so every dense site looks its workload
signature up in the tuned-schedule table (``launch/tune`` writes it) and
routes hits through the registry-backed Pallas kernel where Mosaic
compiles.  ``--tune`` runs the tuning pre-pass first, against the same
serving shapes.  Both default off — the untuned path is untouched.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 32 \
        --tune --registry /tmp/musicgen.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.registry import ScheduleRegistry
from repro.models import steps as S
from repro.models import transformer as T


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, gen_len: int):
        self.rid = rid
        self.prompt = prompt
        self.gen_len = gen_len
        self.generated: List[int] = []
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None


def serve_once(
    cfg,
    *,
    requests: int = 16,
    batch: int = 4,
    prompt_len: int = 32,
    gen_len: int = 32,
    max_len: int = 128,
    seed: int = 0,
    registry: Union[str, ScheduleRegistry, None] = None,
) -> Dict[str, Any]:
    """Run the continuous-batching serve loop once; return the summary.

    ``registry``: tuned-schedule table (path or ScheduleRegistry) to serve
    with.  When given, the summary grows a ``"registry"`` block with the
    per-contraction hit/miss/routed counters from the traced steps.
    """
    rng = np.random.default_rng(seed)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))

    if isinstance(registry, str):
        registry = ScheduleRegistry(registry)
    if registry is not None:
        from repro.kernels import ops as K
        K.reset_serving_stats()

    serve_step = jax.jit(S.make_decode_step(cfg, registry=registry),
                         donate_argnums=(2,))
    prefill_one = jax.jit(S.make_prefill_step(cfg, max_len=max_len,
                                              registry=registry))

    def make_inputs(tokens_np):
        if cfg.frontend == "tokens":
            return {"tokens": jnp.asarray(tokens_np, jnp.int32)}
        b, s = tokens_np.shape
        emb = np.take(np.asarray(
            jax.random.normal(jax.random.PRNGKey(1), (cfg.vocab, cfg.d_model),
                              jnp.float32)), tokens_np, axis=0)
        return {"embeds": jnp.asarray(emb)}

    # request pool
    pool = [Request(i, rng.integers(0, cfg.vocab, (prompt_len,)), gen_len)
            for i in range(requests)]
    pending = list(pool)
    done: List[Request] = []

    # continuous batch state: per-slot request + shared cache
    b = batch
    caches = T.init_cache(cfg, b, max_len)
    slots: List[Optional[Request]] = [None] * b
    slot_len = np.zeros(b, np.int32)

    t0 = time.perf_counter()
    decode_steps = 0
    step_times: List[float] = []
    # NOTE (batched-cache simplification): a production server tracks
    # per-slot cache lengths; here admission happens in waves (all slots
    # share cache_len), which is exact because prompts are equal-length.
    while pending or any(s is not None for s in slots):
        # admit a wave when all slots are free
        if all(s is None for s in slots) and pending:
            wave = [pending.pop(0) for _ in range(min(b, len(pending)))]
            prompts = np.stack(
                [w.prompt for w in wave]
                + [wave[-1].prompt] * (b - len(wave)))
            last_logits, caches, cache_len = prefill_one(
                params, make_inputs(prompts))
            nxt = np.asarray(jnp.argmax(last_logits, -1), np.int32)
            for i, w in enumerate(wave):
                slots[i] = w
                w.generated.append(int(nxt[i]))
            slot_len[:] = prompt_len
            cur = nxt
        # one decode step for the active wave
        one = make_inputs(cur[:, None])
        t_step = time.perf_counter()
        nxt, logits, caches = serve_step(
            params, one, caches, jnp.asarray(int(slot_len[0]), jnp.int32))
        decode_steps += 1
        slot_len += 1
        nxt = np.asarray(nxt, np.int32)  # device sync closes the step timer
        step_times.append(time.perf_counter() - t_step)
        for i, r in enumerate(slots):
            if r is None:
                continue
            r.generated.append(int(nxt[i]))
            if len(r.generated) >= r.gen_len:
                r.t_done = time.perf_counter()
                done.append(r)
                slots[i] = None
        cur = nxt

    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    lat = [r.t_done - r.t_submit for r in done]
    # steady-state decode throughput: median per-step time, excluding the
    # first step (it pays the decode jit compile) — this is the number the
    # tuned-schedule comparison is about; tokens_per_s keeps the whole-loop
    # view (prefill + compile included)
    steady = step_times[1:] if len(step_times) > 1 else step_times
    step_p50 = float(np.percentile(steady, 50))
    summary = {
        "arch": cfg.name,
        "requests": len(done),
        "decode_steps": decode_steps,
        "tokens": total_tokens,
        "tokens_per_s": round(total_tokens / dt, 1),
        "decode_step_p50_ms": round(step_p50 * 1e3, 3),
        "decode_tokens_per_s": round(b / step_p50, 1),
        "latency_p50_s": round(float(np.percentile(lat, 50)), 3),
        "latency_p95_s": round(float(np.percentile(lat, 95)), 3),
    }
    if registry is not None:
        from repro.kernels import ops as K
        summary["registry"] = {
            "path": registry.path,
            "size": len(registry),
            "serving": K.serving_stats(reset=True),
        }
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--registry", default=None,
                    help="tuned-schedule registry JSON to serve with")
    ap.add_argument("--tune", action="store_true",
                    help="run the tuning pre-pass before serving "
                         "(requires --registry)")
    ap.add_argument("--tune-budget-s", type=float, default=4.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke()

    registry = None
    if args.registry:
        registry = ScheduleRegistry(args.registry)
        if args.tune:
            from repro.launch.tune import tune_model
            report = tune_model(
                cfg, registry=registry, registry_path=args.registry,
                budget_s=args.tune_budget_s, smoke=False,  # cfg already set
                batch=args.batch, prompt_len=args.prompt_len,
                max_len=args.max_len)
            print("[serve] tuned:", json.dumps(
                {k: report[k] for k in ("n_harvested", "n_tuned",
                                        "flop_share_covered",
                                        "registry_size", "tune_time_s")}),
                flush=True)
    elif args.tune:
        ap.error("--tune requires --registry")

    summary = serve_once(
        cfg, requests=args.requests, batch=args.batch,
        prompt_len=args.prompt_len, gen_len=args.gen_len,
        max_len=args.max_len, seed=args.seed, registry=registry)
    print("[serve] done:", json.dumps(summary), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
