"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests and benches must keep seeing 1 CPU
device; only the dry-run sets ``xla_force_host_platform_device_count=512``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    # jax >= 0.5 takes axis_types (Auto = GSPMD-propagated, our semantics);
    # jax 0.4.x has neither the kwarg nor AxisType, and Auto is its only
    # behavior — so omitting the kwarg there is the same mesh.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 single pod (data=16, model=16) or 2 pods = 512 chips
    (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests / elastic remesh)."""
    return _make(shape, axes)
