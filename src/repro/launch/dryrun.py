import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL step function (train_step with AdamW for
``train_*``, prefill for ``prefill_*``, serve_step with the KV/state cache
for ``decode_*``/``long_*``), lowers it with ShapeDtypeStruct stand-ins
(zero allocation), compiles it against the production mesh, and records:

  * ``memory_analysis()``   — proves the cell fits per-device HBM,
  * ``cost_analysis()``     — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the post-SPMD HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),

into ``results/dryrun/<arch>__<shape>__<mesh>.json`` (resumable: existing
files are skipped unless --force).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, input_specs, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.optim.schedules import constant
from repro.runtime import sharding as SH

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Collective ops whose operand bytes feed the roofline collective term.
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str):
    """Sum operand bytes per collective kind from post-SPMD HLO text."""
    totals = {}
    counts = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "=" not in line:
            continue
        kind = m.group(1)
        # operands are inside the outermost call parens, after the op name
        paren = line.find("(", line.find(m.group(0)))
        if paren < 0:
            continue
        operands = line[paren:]
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(operands)
        )
        totals[kind] = totals.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return totals, counts


def build_step(cfg, cell, mesh):
    """Returns (jitted_fn, example_args_tree) for the cell kind.

    Params are FSDP-sharded (TP spec + data axis on large leaves; GSPMD
    inserts the per-layer all-gather inside the period scan); optimizer
    state gets the same treatment on every leaf (ZeRO-1/2).  Train donates
    (params, opt); decode donates the cache (serving updates in place)."""
    specs = input_specs(cfg, cell)
    param_shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    tp_specs = SH.param_pspecs(param_shapes, mesh,
                               special_kv_heads=cfg.n_kv_heads)
    # train: FSDP (gather per use); serving: static TP + 2D experts only
    # (no per-step weight gathers on the latency path)
    pspecs = (SH.fsdp_pspecs(tp_specs, param_shapes, mesh)
              if cell.kind == "train" else tp_specs)
    psh = SH.named(mesh, pspecs)

    def batch_shardings():
        return jax.tree.map(
            lambda s: SH.named(mesh, SH.batch_pspec(mesh, s.shape[0],
                                                    len(s.shape))),
            specs["batch"],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    if cell.kind == "train":
        opt_shapes = jax.eval_shape(
            lambda p: adamw_init(p, keep_master=True), param_shapes)
        # step counter replicated; moments/master = param spec + ZeRO data axis
        zspecs = SH.zero_pspecs(tp_specs, param_shapes, mesh)
        from jax.sharding import PartitionSpec as P
        from repro.optim.adamw import AdamWState
        ospecs = AdamWState(step=P(), mu=zspecs, nu=zspecs, master=zspecs)
        osh = SH.named(mesh, ospecs)
        fn = S.make_train_step(cfg, constant(3e-4))
        jf = jax.jit(fn, in_shardings=(psh, osh, batch_shardings()),
                     donate_argnums=(0, 1))
        return jf, (param_shapes, opt_shapes, specs["batch"])

    if cell.kind == "prefill":
        fn = S.make_prefill_step(cfg, max_len=cell.seq_len)
        jf = jax.jit(fn, in_shardings=(psh, batch_shardings()))
        return jf, (param_shapes, specs["batch"])

    # decode
    fn = S.make_decode_step(cfg)
    cspecs = SH.cache_pspecs(specs["caches"], mesh, cell.global_batch,
                             cfg.n_kv_heads)
    csh = SH.named(mesh, cspecs)
    from jax.sharding import NamedSharding, PartitionSpec as P
    jf = jax.jit(fn,
                 in_shardings=(psh, batch_shardings(), csh,
                               NamedSharding(mesh, P())),
                 donate_argnums=(2,))
    return jf, (param_shapes, specs["batch"], specs["caches"],
                specs["cache_len"])


def param_shapes_to_zeros(shapes):
    # eval_shape-compatible stand-in tree (adamw_init only reads shape/dtype)
    return shapes


def run_cell(arch: str, shape: str, mesh_kind: str, force: bool = False,
             out_dir: Path = RESULTS_DIR) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape}__{mesh_kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cell not in shapes_for(cfg):
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "full-attention arch: long_500k inapplicable"}
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    SH.FALLBACKS.clear()  # per-cell record (the sweep reuses the process)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "kind": cell.kind,
           "seq_len": cell.seq_len, "global_batch": cell.global_batch}
    try:
        with mesh, SH.use_mesh(mesh):
            jf, args = build_step(cfg, cell, mesh)
            lowered = jf.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll, coll_counts = collective_bytes(hlo)
            from repro.analysis.hlo_parse import loop_corrected_totals
            corr = loop_corrected_totals(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory_analysis={
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            cost_analysis={
                k: float(v) for k, v in (cost or {}).items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals") or k.startswith("bytes"))
            },
            collective_bytes=coll,
            collective_counts=coll_counts,
            corrected={
                "flops": corr["flops"],
                "mem_bytes": corr["mem_bytes"],
                "coll_bytes": {k: float(v)
                               for k, v in corr["coll_bytes"].items()},
                "coll_bytes_total": corr["coll_bytes_total"],
                "while_trips": corr["while_trips"][:40],
            },
            n_params=T.count_params(cfg),
            n_params_active=T.count_params(cfg, active_only=True),
            sharding_fallbacks=list(SH.FALLBACKS),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for cell in shapes_for(cfg):
                for m in meshes:
                    cells.append((arch, cell.name, m))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, m in cells:
        rec = run_cell(arch, shape, m, force=args.force, out_dir=Path(args.out))
        status = rec["status"]
        extra = ""
        if status == "ok":
            ma = rec.get("memory_analysis", {})
            extra = (f" compile={rec['compile_s']}s"
                     f" temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
                     f" args={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
        elif status == "error":
            failures += 1
            extra = " " + rec["error"][:200]
        print(f"[dryrun] {arch} x {shape} x {m}: {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
