"""End-to-end training launcher.

Runs a real training loop for any ``--arch`` (smoke-scaled by default so it
trains on this CPU container; ``--full`` uses the published config for fleet
runs) with the whole substrate engaged: deterministic host-sharded data,
sharded AdamW, checkpoint/restart, straggler watchdog, optional failure
injection, optional int8 gradient compression, microbatched grad accum.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a fleet the same script runs under ``jax.distributed.initialize()`` with
the production mesh from ``mesh.py``; on 1 CPU device the mesh is (1, 1).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import make_dataset
from repro.models import steps as S
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.optim.schedules import cosine_with_warmup
from repro.runtime import sharding as SH
from repro.runtime.compress import compress_grads, ef_init
from repro.runtime.ft import FailureInjector, FaultTolerantRunner, StragglerWatchdog


def build(args, registry=None):
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke()
    lr_fn = cosine_with_warmup(args.lr, warmup=max(10, args.steps // 20),
                               total=args.steps)
    step_fn = S.make_train_step(
        cfg, lr_fn, n_microbatches=args.microbatches,
        weight_decay=args.weight_decay, registry=registry)
    return cfg, step_fn


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="published config (fleet scale); default smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--weight-decay", type=float, default=0.1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, nargs="*", default=None,
                    help="inject failures at these steps (FT demo)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="auto",
                    help="'auto' (all devices on the data axis) | 'single' | 'multi'")
    ap.add_argument("--registry", default=None,
                    help="tuned-schedule registry JSON (dense sites consult "
                         "it at trace time; default: plain XLA path)")
    args = ap.parse_args(argv)

    registry = None
    if args.registry:
        from repro.core.registry import ScheduleRegistry
        registry = ScheduleRegistry(args.registry)
    cfg, raw_step = build(args, registry=registry)
    if args.mesh == "auto":
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    ds = make_dataset(cfg, None, seed=args.seed, global_batch=args.batch,
                      seq_len=args.seq)

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params, keep_master=cfg.dtype != "float32")
    ef = ef_init(params) if args.compress_grads else None

    if args.compress_grads:
        def step_with_ef(state, batch):
            params, opt, ef = state
            lr_fn = cosine_with_warmup(args.lr, 10, args.steps)
            loss_fn = S.make_loss_fn(cfg, registry=registry)
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads, ef = compress_grads(grads, ef)
            from repro.optim import adamw_update
            p2, o2, gn = adamw_update(grads, opt, params, lr_fn(opt.step),
                                      weight_decay=args.weight_decay,
                                      max_grad_norm=1.0)
            return (p2, o2, ef), dict(metrics, grad_norm=gn)

        step_jit = jax.jit(step_with_ef, donate_argnums=(0,))
        state = (params, opt, ef)
    else:
        step_jit = jax.jit(lambda st, b: _pack(raw_step(st[0], st[1], b)),
                           donate_argnums=(0,))
        state = (params, opt)

    def _pack(r):
        p, o, m = r
        return (p, o), m

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in ds.batch(step).items()}

    ckpt = CheckpointManager(args.ckpt_dir or "/tmp/repro_ckpt",
                             keep_n=3)
    injector = FailureInjector(args.fail_at) if args.fail_at else None
    watchdog = StragglerWatchdog(n_hosts=max(1, mesh.shape.get("data", 1)))
    runner = FaultTolerantRunner(
        step_jit, ckpt, save_every=args.save_every, injector=injector,
        extras_fn=lambda s: {"data_seed": args.seed, "arch": cfg.name})

    # resume if a checkpoint exists
    start = 0
    restored = ckpt.restore_latest(state)
    if restored is not None:
        start, state, extras = restored
        print(f"[train] resumed from step {start}", flush=True)

    t0 = time.time()
    losses = []

    def log_hook(step, m):
        losses.append(m["loss"])
        # single-host container: per-host time == step time
        watchdog.record(step, np.array([m["step_time_s"]]))
        if step % args.log_every == 0:
            tput = args.batch * args.seq / m["step_time_s"]
            print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                  f"ce {m.get('ce', float('nan')):.4f} "
                  f"gnorm {m['grad_norm']:.3f} tok/s {tput:,.0f}", flush=True)

    with mesh, SH.use_mesh(mesh):
        state, final_step, metrics = runner.run(
            state, batch_fn, start, args.steps - start, hooks=[log_hook])

    dt = time.time() - t0
    summary = {
        "arch": cfg.name, "steps": final_step, "wall_s": round(dt, 1),
        "loss_first": losses[0] if losses else None,
        "loss_last": float(np.mean(losses[-5:])) if losses else None,
        "restarts": runner.restarts,
        "straggler_events": len(watchdog.events),
        "tokens_per_s": round(args.batch * args.seq * len(losses) / dt, 1),
    }
    print("[train] done:", json.dumps(summary), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
